//! Deterministic pseudo-random number generation (substrate).
//!
//! The offline build has no `rand` crate, so the fleet simulator carries its
//! own PRNG: [`Pcg32`] (PCG-XSH-RR 64/32) seeded through SplitMix64, the
//! standard small-state generator with good statistical quality. Everything
//! stochastic in the system — channel fading, device placement, data
//! synthesis, shard assignment, property tests — flows through this module,
//! so every experiment is reproducible from a single `u64` seed.

/// SplitMix64 step: used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create from a seed and a stream id; distinct streams are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = (splitmix64(&mut sm) ^ stream) | 1;
        let mut rng = Pcg32 { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a child generator (e.g. one per device) — avoids correlated
    /// streams when fanning out.
    pub fn fork(&mut self, stream: u64) -> Self {
        let seed = (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32());
        Self::new(seed, stream)
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform in `[0, 1)` with 32 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        f64::from(self.next_u32()) / 4294967296.0
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in `[0, n)` (Lemire rejection).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u32();
        let mut m = u64::from(x) * u64::from(n);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = u64::from(x) * u64::from(n);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller (both branches cached would need
    /// state; we draw fresh pairs — fine at this call volume).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/λ) — used for Rayleigh
    /// fading power (|h|² of a complex Gaussian is exponential).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let mut u = self.uniform();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -(1.0 - u).ln() / lambda
    }

    /// Gamma(shape, scale) via Marsaglia–Tsang; used by the Dirichlet
    /// non-IID partitioner.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * scale;
            }
        }
    }

    /// Dirichlet sample of dimension `alpha.len()`.
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let raw: Vec<f64> = alpha.iter().map(|&a| self.gamma(a, 1.0)).collect();
        let sum: f64 = raw.iter().sum();
        if sum <= 0.0 {
            // pathological underflow: fall back to uniform
            return vec![1.0 / alpha.len() as f64; alpha.len()];
        }
        raw.into_iter().map(|x| x / sum).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u), "{u}");
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg32::seeded(8);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(10);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn gamma_mean_variance() {
        let mut r = Pcg32::seeded(12);
        let (shape, scale) = (3.0, 2.0);
        let n = 30_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(shape, scale)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - shape * scale).abs() < 0.15, "{mean}");
    }

    #[test]
    fn gamma_small_shape_positive() {
        let mut r = Pcg32::seeded(13);
        for _ in 0..1000 {
            assert!(r.gamma(0.3, 1.0) > 0.0);
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg32::seeded(14);
        for _ in 0..100 {
            let d = r.dirichlet(&[0.5; 10]);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(15);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(16);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Pcg32::seeded(99);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
