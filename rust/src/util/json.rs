//! Minimal JSON (substrate): value model, recursive-descent parser, writer.
//!
//! The offline build has no `serde`; this module covers everything the
//! system needs — reading `artifacts/manifest.json`, writing experiment
//! results and metric dumps. It implements the full JSON grammar (RFC 8259)
//! minus `\u` surrogate-pair edge cases beyond the BMP (accepted, replaced).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` for deterministic output ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64; NaN/Inf serialize as `null`).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (sorted keys ⇒ deterministic output).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte position.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset the parser stopped at.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------- accessors

    /// Number value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer value, if this is an integral `Num`.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// String value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array view, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object view, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `get("models")` then chain.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Array element `i`, if this is an `Arr` that long.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        self.as_arr().and_then(|a| a.get(i))
    }

    // -------------------------------------------------------- constructors

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a number array from a slice.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -------------------------------------------------------------- parse

    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Parse a JSON document from a file.
    pub fn parse_file(path: impl AsRef<std::path::Path>) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))?)
    }

    // -------------------------------------------------------------- write

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    /// Pretty-print to a file, creating parent directories.
    pub fn write_file(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_pretty())?;
        Ok(())
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; null is the least-surprising encoding.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo ≈\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ≈"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"a":[1,2.5,true,null,"s"],"b":{"c":-3}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.0])),
            ("name", Json::str("defl")),
        ]);
        let p = v.to_pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
        assert!(p.contains('\n'));
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn as_u64_semantics() {
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
        assert_eq!(Json::Num(7.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": "hlo-text",
          "models": {"mlp": {"param_count": 2410,
            "params": [{"name": "fc1_w", "shape": [64, 32]}],
            "train": {"16": {"file": "mlp_train_b16.hlo.txt"}}}}
        }"#;
        let v = Json::parse(src).unwrap();
        let m = v.get("models").unwrap().get("mlp").unwrap();
        assert_eq!(m.get("param_count").unwrap().as_u64(), Some(2410));
        let shape = m.get("params").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.idx(0).unwrap().as_u64(), Some(64));
    }
}
