//! Declarative command-line parsing (substrate; no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, subcommands, defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
enum Kind {
    Flag,          // boolean, present/absent
    Value(String), // takes a value; payload = default ("" = required)
}

#[derive(Clone, Debug)]
struct Opt {
    name: String,
    kind: Kind,
    help: String,
    required: bool,
}

/// Builder for one (sub)command's options.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// Binary name shown in usage/help.
    pub bin: String,
    /// One-line description shown in help.
    pub about: String,
    opts: Vec<Opt>,
    positional: Vec<(String, String)>, // (name, help)
}

/// Parse result: option values + positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Positional arguments, in order.
    pub positional: Vec<String>,
}

/// Argument-parsing error (message already user-readable).
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Cli {
    /// Declare a command (options/flags are chained on).
    pub fn new(bin: &str, about: &str) -> Self {
        Cli { bin: bin.into(), about: about.into(), ..Default::default() }
    }

    /// Boolean flag (`--verbose`).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts
            .push(Opt { name: name.into(), kind: Kind::Flag, help: help.into(), required: false });
        self
    }

    /// Option with a default value.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.into(),
            kind: Kind::Value(default.into()),
            help: help.into(),
            required: false,
        });
        self
    }

    /// Required option.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.into(),
            kind: Kind::Value(String::new()),
            help: help.into(),
            required: true,
        });
        self
    }

    /// Declare a positional argument (for help text; not enforced).
    pub fn pos(mut self, name: &str, help: &str) -> Self {
        self.positional.push((name.into(), help.into()));
        self
    }

    /// Rendered `--help` text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.bin, self.about, self.bin);
        for (p, _) in &self.positional {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for o in &self.opts {
            let left = match &o.kind {
                Kind::Flag => format!("--{}", o.name),
                Kind::Value(d) if d.is_empty() => format!("--{} <value> (required)", o.name),
                Kind::Value(d) => format!("--{} <value> [default: {}]", o.name, d),
            };
            s.push_str(&format!("  {left:<44} {}\n", o.help));
        }
        for (p, h) in &self.positional {
            s.push_str(&format!("  <{p}>  {h}\n"));
        }
        s
    }

    /// Parse a raw arg list (excluding argv[0]).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut out = Args::default();
        // seed defaults
        for o in &self.opts {
            match &o.kind {
                Kind::Flag => {
                    out.flags.insert(o.name.clone(), false);
                }
                Kind::Value(d) if !d.is_empty() => {
                    out.values.insert(o.name.clone(), d.clone());
                }
                _ => {}
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.help_text()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| {
                        CliError(format!("unknown option --{name}\n\n{}", self.help_text()))
                    })?;
                match &opt.kind {
                    Kind::Flag => {
                        if inline.is_some() {
                            return Err(CliError(format!("--{name} takes no value")));
                        }
                        out.flags.insert(name, true);
                    }
                    Kind::Value(_) => {
                        let v = match inline {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                            }
                        };
                        out.values.insert(name, v);
                    }
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if o.required && !out.values.contains_key(&o.name) {
                return Err(CliError(format!(
                    "missing required --{}\n\n{}",
                    o.name,
                    self.help_text()
                )));
            }
        }
        Ok(out)
    }
}

impl Args {
    /// Raw option value, if the option exists.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Option value as an owned string (empty when absent).
    pub fn str(&self, name: &str) -> String {
        self.values.get(name).cloned().unwrap_or_default()
    }

    /// Whether a boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Option value parsed as u64.
    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected integer, got {:?}", self.str(name))))
    }

    /// Option value parsed as usize.
    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        Ok(self.u64(name)? as usize)
    }

    /// Option value parsed as f64.
    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected number, got {:?}", self.str(name))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("defl", "test")
            .opt("rounds", "10", "number of rounds")
            .opt("dataset", "mnist", "dataset name")
            .req("out", "output path")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_and_required() {
        let a = cli().parse(&argv(&["--out", "x.json"])).unwrap();
        assert_eq!(a.str("rounds"), "10");
        assert_eq!(a.u64("rounds").unwrap(), 10);
        assert_eq!(a.str("out"), "x.json");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&argv(&[])).is_err());
    }

    #[test]
    fn equals_form_and_flags() {
        let a = cli()
            .parse(&argv(&["--out=o", "--rounds=25", "--verbose"]))
            .unwrap();
        assert_eq!(a.u64("rounds").unwrap(), 25);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&argv(&["--out", "o", "--bogus", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = cli().parse(&argv(&["fig1a", "--out", "o"])).unwrap();
        assert_eq!(a.positional, vec!["fig1a"]);
    }

    #[test]
    fn value_missing_errors() {
        assert!(cli().parse(&argv(&["--out"])).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(cli().parse(&argv(&["--out", "o", "--verbose=yes"])).is_err());
    }

    #[test]
    fn bad_number_reports() {
        let a = cli().parse(&argv(&["--out", "o", "--rounds", "ten"])).unwrap();
        assert!(a.u64("rounds").is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = cli().help_text();
        assert!(h.contains("--rounds"));
        assert!(h.contains("required"));
        let e = cli().parse(&argv(&["--help"])).unwrap_err();
        assert!(e.0.contains("USAGE"));
    }
}
