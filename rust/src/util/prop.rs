//! Property-based testing runner (substrate; no `proptest` offline).
//!
//! A deliberately small core: a seeded [`Gen`] wraps the system PRNG with
//! convenience samplers, and [`check`] runs a property over `n` random
//! cases, reporting the seed + case index of the first failure so any
//! counterexample is exactly reproducible:
//!
//! ```text
//! property failed at case 17 (rerun with seed 0xDEADBEEF)
//! ```
//!
//! Shrinking is intentionally omitted (cases are generated from compact
//! numeric parameters, so the failing case itself is already small).

use super::rng::Pcg32;

/// Generator handle passed to properties.
pub struct Gen {
    /// The case's RNG (derive further draws from it directly).
    pub rng: Pcg32,
}

impl Gen {
    /// Uniform integer in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    /// Log-uniform positive value — spans magnitudes, good for ε, rates...
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.rng.uniform_in(lo.ln(), hi.ln())).exp()
    }

    /// Fair coin.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Vector of uniform f64 draws.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Vector of uniform f32 draws.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len)
            .map(|_| self.rng.uniform_in(lo as f64, hi as f64) as f32)
            .collect()
    }

    /// Uniformly pick one element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }
}

/// Run `prop` over `n` seeded random cases. Panics (test failure) on the
/// first case returning `Err`, with a reproducible seed in the message.
pub fn check<F>(seed: u64, n: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..n {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut g = Gen { rng: Pcg32::seeded(case_seed) };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case}/{n} (case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

/// Helper: assert two floats are close (returns Err for use in properties).
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(1, 50, |g| {
            count += 1;
            let x = g.f64_in(0.0, 1.0);
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(2, 100, |g| {
            let x = g.usize_in(0, 10);
            if x < 10 {
                Ok(())
            } else {
                Err("hit ten".into())
            }
        });
    }

    #[test]
    fn log_uniform_in_range() {
        check(3, 200, |g| {
            let x = g.log_uniform(1e-6, 1e3);
            if (1e-6..=1e3).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    fn close_accepts_relative_tolerance() {
        assert!(close(1000.0, 1000.001, 1e-5, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-5, "x").is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first: Vec<f64> = Vec::new();
        check(7, 10, |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second: Vec<f64> = Vec::new();
        check(7, 10, |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
