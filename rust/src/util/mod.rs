//! Shared substrates built from scratch for the offline environment:
//! PRNG, statistics, JSON, CLI parsing, thread pool, property testing,
//! logging. See DESIGN.md §3 for the substitution table.

pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
