//! Shared substrates built from scratch for the offline environment:
//! PRNG, statistics, JSON, CLI parsing, thread pool, property testing,
//! logging. See DESIGN.md §3 for the substitution table.

/// Declarative argument parsing for the binaries/examples.
pub mod cli;
/// JSON value type, parser and writer.
pub mod json;
/// Leveled stderr logging with virtual-time stamps.
pub mod logging;
/// Minimal property-testing harness.
pub mod prop;
/// PCG32 PRNG + distributions (the only randomness source).
pub mod rng;
/// Descriptive statistics.
pub mod stats;
/// Fixed thread pool + `parallel_map`.
pub mod threadpool;
