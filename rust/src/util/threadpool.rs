//! Minimal thread pool + scoped parallel map (substrate; no `tokio`/`rayon`).
//!
//! The coordinator's device fleet is logically parallel (paper: synchronous
//! rounds, per-round time = max over devices). On this testbed the fleet is
//! executed either sequentially or via [`parallel_map`], which spawns scoped
//! threads in chunks. Virtual time (simclock) is what implements the paper's
//! synchronous `max`; wall-clock parallelism is just an execution detail.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// A fixed-size pool executing boxed jobs; join with [`ThreadPool::wait`].
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl ThreadPool {
    /// Pool with `threads` workers (min 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        job();
                        let (lock, cvar) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        if *p == 0 {
                            cvar.notify_all();
                        }
                    }
                    Err(_) => break,
                }
            }));
        }
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Queue one job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx.as_ref().unwrap().send(Box::new(f)).unwrap();
    }

    /// Block until every submitted job has finished.
    pub fn wait(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped parallel map: applies `f` to each item on up to `threads` OS
/// threads and returns results in input order. Falls back to sequential
/// when `threads <= 1` or the input is tiny.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    if threads <= 1 || items.len() <= 1 {
        return items.into_iter().map(|x| f(x)).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let slots_mutex = Mutex::new(&mut slots);
    thread::scope(|s| {
        for _ in 0..threads.min(n) {
            s.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, x)) => {
                        let r = f(x);
                        slots_mutex.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|o| o.expect("worker died")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_wait_idempotent() {
        let pool = ThreadPool::new(2);
        pool.wait(); // nothing submitted
        let c = Arc::new(AtomicUsize::new(0));
        let cc = Arc::clone(&c);
        pool.execute(move || {
            cc.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait();
        pool.wait();
        assert_eq!(c.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<usize> = (0..200).collect();
        let ys = parallel_map(xs.clone(), 8, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_sequential_fallback() {
        let ys = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(ys, vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_empty() {
        let ys: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }
}
