//! Leveled stderr logger (substrate) with wall-clock and virtual-clock
//! stamps. Level comes from `DEFL_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, AtomicU64, Ordering};
use std::time::Instant;

/// Log severity (ordered: Error < Warn < Info < Debug).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn parse(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // lazily initialised
/// Virtual time in microseconds, mirrored from the active simclock so log
/// lines can carry both clocks.
static VIRT_US: AtomicU64 = AtomicU64::new(0);

fn start() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Set the global log threshold.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Current global log threshold.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let l = Level::parse(&std::env::var("DEFL_LOG").unwrap_or_default());
        LEVEL.store(l as u8, Ordering::Relaxed);
        return l;
    }
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Mirror the coordinator's virtual clock (seconds) into log stamps.
pub fn set_virtual_time(seconds: f64) {
    VIRT_US.store((seconds * 1e6) as u64, Ordering::Relaxed);
}

/// Emit one line (the `log_*!` macros route here).
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if l > level() {
        return;
    }
    let wall = start().elapsed().as_secs_f64();
    let virt = VIRT_US.load(Ordering::Relaxed) as f64 / 1e6;
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{wall:9.3}s|vt {virt:10.3}s] {} {args}", l.tag());
}

/// Log at [`util::logging::Level::Error`](crate::util::logging::Level) (format_args syntax).
#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
/// Log at [`util::logging::Level::Warn`](crate::util::logging::Level) (format_args syntax).
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
/// Log at [`util::logging::Level::Info`](crate::util::logging::Level) (format_args syntax).
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
/// Log at [`util::logging::Level::Debug`](crate::util::logging::Level) (format_args syntax).
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("error"), Level::Error);
        assert_eq!(Level::parse("TRACE"), Level::Trace);
        assert_eq!(Level::parse("bogus"), Level::Info);
    }

    #[test]
    fn ordering_matches_severity() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_level_round_trips() {
        let prev = level();
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(prev);
    }

    #[test]
    fn virtual_time_stamp_updates() {
        set_virtual_time(12.5);
        assert_eq!(VIRT_US.load(Ordering::Relaxed), 12_500_000);
        set_virtual_time(0.0);
    }
}
