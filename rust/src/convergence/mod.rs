//! Convergence theory of DEFL — Theorem 1, Corollaries 1–2, Remark 3.
//!
//! These closed forms are what turns the delay models into an end-to-end
//! *overall time* objective:
//!
//! ```text
//! (10)  E[F(w̄_K) − F*] ≤ 8‖w₀−w*‖²/√(MK) + σ²/(2bL√(MK)) + σ²M(V−1)/(bLK)
//! (12)  H = c/(b²ε²·M·ν·log(1/θ)) + c·M/(b·ε)
//! (R3)  V = ν·log(1/θ)
//! (8)   T = T_cm + V·T_cp
//! (13)  𝒯 = H·T
//! ```

/// Problem constants for the bound (10).
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// ‖w₀ − w*‖² — squared distance of the initialization from optimum.
    pub w0_dist_sq: f64,
    /// σ² — per-device stochastic gradient variance bound (Assumption 2).
    pub sigma_sq: f64,
    /// L — smoothness constant (Assumption 1).
    pub smoothness: f64,
}

impl Default for BoundParams {
    fn default() -> Self {
        // Unit-scale constants; the experiments only use ratios/shapes.
        BoundParams { w0_dist_sq: 1.0, sigma_sq: 1.0, smoothness: 1.0 }
    }
}

/// Corollary 1 (eq. 10): optimality-gap bound after `k` gradient steps with
/// `m` devices, batch `b` and `v` local rounds.
pub fn gap_bound(p: &BoundParams, m: usize, k: usize, b: usize, v: usize) -> f64 {
    assert!(m > 0 && k > 0 && b > 0 && v > 0);
    let (mf, kf, bf, vf) = (m as f64, k as f64, b as f64, v as f64);
    let term1 = 8.0 * p.w0_dist_sq / (mf * kf).sqrt();
    let term2 = p.sigma_sq / (2.0 * bf * p.smoothness * (mf * kf).sqrt());
    let term3 = p.sigma_sq * mf * (vf - 1.0) / (bf * p.smoothness * kf);
    term1 + term2 + term3
}

/// Remark 3: local rounds to reach local accuracy θ: `V = ν·log(1/θ)`.
/// Clamped to ≥ 1 (a device always takes at least one step).
pub fn local_rounds(nu: f64, theta: f64) -> usize {
    assert!(nu > 0.0, "nu must be positive");
    assert!((0.0..=1.0).contains(&theta), "theta in [0,1], got {theta}");
    if theta <= f64::MIN_POSITIVE {
        return usize::MAX / 2; // θ → 0 needs unboundedly many rounds
    }
    let v = nu * (1.0 / theta).ln();
    // epsilon guard: ν·log(1/θ) that is integral up to float error should
    // not ceil to the next integer (e.g. 2·1.5 = 3.0000000000000004).
    (v - 1e-9).ceil().max(1.0) as usize
}

/// Inverse of `local_rounds` on the continuous relaxation: θ for a given V.
pub fn theta_for_rounds(nu: f64, v: f64) -> f64 {
    assert!(nu > 0.0 && v >= 0.0);
    (-v / nu).exp()
}

/// Eq. (12): communication rounds to reach ε-global accuracy.
///
/// `c` approximates the big-O constant; the paper's evaluation treats it as
/// a fixed scale. `alpha = log(1/θ)` is the auxiliary variable of Section V.
pub fn rounds_to_epsilon(c: f64, b: f64, eps: f64, m: usize, nu: f64, alpha: f64) -> f64 {
    assert!(c > 0.0 && b >= 1.0 && eps > 0.0 && m > 0 && nu > 0.0 && alpha > 0.0);
    let mf = m as f64;
    c / (b * b * eps * eps * mf * nu * alpha) + c * mf / (b * eps)
}

/// Eq. (8): wall time of one synchronous round.
pub fn round_wall_time(t_cm: f64, v: usize, t_cp: f64) -> f64 {
    assert!(t_cm >= 0.0 && t_cp >= 0.0);
    t_cm + v as f64 * t_cp
}

/// Eq. (13): overall time 𝒯 = H·T (continuous H allowed — the optimizer
/// works on the relaxation; the driver rounds H up to an integer).
pub fn overall_time(h: f64, t_round: f64) -> f64 {
    assert!(h >= 0.0 && t_round >= 0.0);
    h * t_round
}

/// The complete objective (14): 𝒯(b, α) for given delay inputs.
/// `t_cp_per_sample` is the bottleneck `G·bits/f` so that `T_cp = b·that`.
#[allow(clippy::too_many_arguments)] // the paper's (14) takes 8 natural knobs
pub fn objective(
    c: f64,
    eps: f64,
    m: usize,
    nu: f64,
    t_cm: f64,
    t_cp_per_sample: f64,
    b: f64,
    alpha: f64,
) -> f64 {
    let h = rounds_to_epsilon(c, b, eps, m, nu, alpha);
    let t_cp = b * t_cp_per_sample;
    let t = t_cm + nu * alpha * t_cp; // V = ν·α on the continuous relaxation
    h * t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn gap_bound_decreases_in_k() {
        let p = BoundParams::default();
        let g1 = gap_bound(&p, 10, 100, 32, 5);
        let g2 = gap_bound(&p, 10, 1000, 32, 5);
        assert!(g2 < g1);
    }

    #[test]
    fn gap_bound_decreases_in_b() {
        // Remark 2: batch size b reduces the variance terms by 1/b.
        let p = BoundParams::default();
        let g1 = gap_bound(&p, 10, 500, 8, 5);
        let g2 = gap_bound(&p, 10, 500, 64, 5);
        assert!(g2 < g1);
    }

    #[test]
    fn gap_bound_increases_in_v() {
        // More local drift (V−1 term) hurts the bound.
        let p = BoundParams::default();
        assert!(gap_bound(&p, 10, 500, 32, 20) > gap_bound(&p, 10, 500, 32, 1));
    }

    #[test]
    fn v_equals_one_recovers_theorem1_shape() {
        // V=1 kills term3 entirely.
        let p = BoundParams { sigma_sq: 2.0, ..Default::default() };
        let g = gap_bound(&p, 4, 100, 1, 1);
        let expected = 8.0 / (400f64).sqrt() + 2.0 / (2.0 * (400f64).sqrt());
        assert!((g - expected).abs() < 1e-12);
    }

    #[test]
    fn local_rounds_basic() {
        // ν=3, θ=e⁻² ⇒ V = 6
        let v = local_rounds(3.0, (-2.0f64).exp());
        assert_eq!(v, 6);
        assert_eq!(local_rounds(3.0, 1.0), 1); // θ=1: no improvement, ≥1 step
    }

    #[test]
    fn local_rounds_monotone_decreasing_in_theta() {
        let v_loose = local_rounds(4.0, 0.5);
        let v_tight = local_rounds(4.0, 0.05);
        assert!(v_tight > v_loose);
    }

    #[test]
    fn theta_rounds_roundtrip() {
        let nu = 2.5;
        for &v in &[1.0, 3.0, 10.0] {
            let theta = theta_for_rounds(nu, v);
            assert!((nu * (1.0 / theta).ln() - v).abs() < 1e-9);
        }
    }

    #[test]
    fn rounds_decrease_with_work() {
        // More local work (larger α ⇒ smaller θ) reduces H (paper Fig 1d).
        let h_lazy = rounds_to_epsilon(1.0, 32.0, 0.01, 10, 2.0, 0.5);
        let h_hard = rounds_to_epsilon(1.0, 32.0, 0.01, 10, 2.0, 3.0);
        assert!(h_hard < h_lazy);
    }

    #[test]
    fn rounds_decrease_with_batch() {
        let h_small = rounds_to_epsilon(1.0, 8.0, 0.01, 10, 2.0, 1.0);
        let h_large = rounds_to_epsilon(1.0, 64.0, 0.01, 10, 2.0, 1.0);
        assert!(h_large < h_small);
    }

    #[test]
    fn overall_time_composition() {
        let t = round_wall_time(0.5, 4, 0.1);
        assert!((t - 0.9).abs() < 1e-12);
        assert!((overall_time(10.0, t) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn objective_tradeoff_exists() {
        // 𝒯 should not be monotone in α: talking less (bigger α) helps
        // until computation dominates — the paper's whole premise.
        let f = |alpha: f64| objective(1.0, 0.01, 10, 2.0, 0.2, 1e-3, 4.0, alpha);
        let small = f(0.05);
        let mid = f(1.0);
        let huge = f(500.0);
        assert!(mid < small, "more work should beat almost-no-work");
        assert!(mid < huge, "unbounded work must eventually lose");
    }

    #[test]
    fn prop_objective_positive_finite() {
        prop::check(0x0B1, 200, |g| {
            let b = g.f64_in(1.0, 256.0);
            let alpha = g.log_uniform(1e-3, 1e2);
            let eps = g.log_uniform(1e-4, 0.5);
            let m = g.usize_in(1, 100);
            let t_cm = g.f64_in(0.01, 5.0);
            let tps = g.log_uniform(1e-6, 1e-2);
            let t = objective(1.0, eps, m, 2.0, t_cm, tps, b, alpha);
            if t.is_finite() && t > 0.0 {
                Ok(())
            } else {
                Err(format!("objective {t}"))
            }
        });
    }
}
