//! Synthetic MNIST/CIFAR-shaped datasets (substitution for the real
//! downloads — DESIGN.md §4).
//!
//! Construction: each class `k` gets a deterministic prototype image built
//! from a few low-frequency 2-D cosine modes whose phases/frequencies are
//! seeded by `k`. A sample is `clip(prototype + per-sample Gaussian pixel
//! noise + global intensity jitter, 0, 1)`, with optional label noise.
//! Low-frequency structure makes classes separable by a small CNN (like
//! MNIST digits) while pixel noise keeps single samples uninformative
//! enough that batch size and local rounds matter — which is what the
//! DEFL experiments need.

use super::Dataset;
use crate::util::rng::Pcg32;

/// Shape + distribution knobs of one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Sample count.
    pub n: usize,
    /// Image height.
    pub height: usize,
    /// Image width.
    pub width: usize,
    /// Image channels.
    pub channels: usize,
    /// Distinct class labels.
    pub classes: usize,
    /// Pixel noise std (in [0,1] intensity units).
    pub noise: f64,
    /// Fraction of labels flipped to a random class.
    pub label_noise: f64,
    /// Number of cosine modes per class prototype.
    pub modes: usize,
}

impl SynthSpec {
    /// 28×28×1, 10 classes — the MNIST stand-in. Noise is tuned so a
    /// small CNN needs tens of communication rounds to exceed 95%
    /// (mirroring MNIST-from-scratch dynamics), not a handful.
    pub fn mnist_like(n: usize) -> Self {
        SynthSpec {
            n,
            height: 28,
            width: 28,
            channels: 1,
            classes: 10,
            noise: 0.95,
            label_noise: 0.03,
            modes: 4,
        }
    }

    /// 32×32×3, 10 classes — the CIFAR-10 stand-in (noisier / harder,
    /// mirroring the real datasets' difficulty gap).
    pub fn cifar_like(n: usize) -> Self {
        SynthSpec {
            n,
            height: 32,
            width: 32,
            channels: 3,
            classes: 10,
            noise: 1.1,
            label_noise: 0.08,
            modes: 6,
        }
    }

    /// 8×8×1 — for the quickstart MLP and fast tests.
    pub fn tiny(n: usize) -> Self {
        SynthSpec {
            n,
            height: 8,
            width: 8,
            channels: 1,
            classes: 10,
            noise: 0.10,
            label_noise: 0.0,
            modes: 3,
        }
    }
}

/// One class's prototype generator parameters.
struct Proto {
    /// (amp, fy, fx, phase_y, phase_x) per mode per channel.
    modes: Vec<(f64, f64, f64, f64, f64)>,
}

fn class_prototype(spec: &SynthSpec, class: usize, seed: u64) -> Vec<Proto> {
    // Seeded per (dataset seed, class) — prototypes are stable across runs.
    (0..spec.channels)
        .map(|ch| {
            let mut rng = Pcg32::new(seed ^ 0x9E37_79B9, (class * 64 + ch) as u64 + 1);
            let modes = (0..spec.modes)
                .map(|_| {
                    (
                        rng.uniform_in(0.25, 0.6),
                        rng.uniform_in(0.5, 3.0),
                        rng.uniform_in(0.5, 3.0),
                        rng.uniform_in(0.0, std::f64::consts::TAU),
                        rng.uniform_in(0.0, std::f64::consts::TAU),
                    )
                })
                .collect();
            Proto { modes }
        })
        .collect()
}

fn render_proto(protos: &[Proto], spec: &SynthSpec, out: &mut [f32]) {
    let (h, w, c) = (spec.height, spec.width, spec.channels);
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                let fy = y as f64 / h as f64;
                let fx = x as f64 / w as f64;
                let mut v = 0.5;
                for &(amp, my, mx, py, px) in &protos[ch].modes {
                    v += amp
                        * (std::f64::consts::TAU * my * fy + py).cos()
                        * (std::f64::consts::TAU * mx * fx + px).cos();
                }
                out[(y * w + x) * c + ch] = v as f32;
            }
        }
    }
}

/// Generate a dataset. Deterministic in `(spec, seed)`; the class
/// prototypes AND the sample noise both derive from `seed`, so train/test
/// splits of the same task must use [`generate_split`] instead.
pub fn generate(spec: &SynthSpec, seed: u64) -> Dataset {
    generate_split(spec, seed, seed)
}

/// Generate a dataset whose *task* (class prototypes) comes from
/// `task_seed` while the samples (noise, label draws) come from
/// `sample_seed`. Train and test sets of one experiment share `task_seed`
/// and differ in `sample_seed` — same classification problem, disjoint
/// noise draws.
pub fn generate_split(spec: &SynthSpec, task_seed: u64, sample_seed: u64) -> Dataset {
    assert!(spec.n > 0 && spec.classes > 1);
    let d = spec.height * spec.width * spec.channels;
    // Pre-render one prototype image per class (task identity).
    let mut proto_imgs = vec![0f32; spec.classes * d];
    for k in 0..spec.classes {
        let protos = class_prototype(spec, k, task_seed);
        render_proto(&protos, spec, &mut proto_imgs[k * d..(k + 1) * d]);
    }

    let mut rng = Pcg32::new(sample_seed, 0xDA7A);
    let mut images = vec![0f32; spec.n * d];
    let mut labels = Vec::with_capacity(spec.n);
    for i in 0..spec.n {
        let k = rng.below(spec.classes as u32) as usize;
        let jitter = rng.normal_ms(0.0, 0.05);
        let dst = &mut images[i * d..(i + 1) * d];
        let src = &proto_imgs[k * d..(k + 1) * d];
        for (o, &p) in dst.iter_mut().zip(src) {
            let noisy = p as f64 + rng.normal_ms(0.0, spec.noise) + jitter;
            *o = noisy.clamp(0.0, 1.0) as f32;
        }
        let label = if spec.label_noise > 0.0 && rng.uniform() < spec.label_noise {
            rng.below(spec.classes as u32) as i32
        } else {
            k as i32
        };
        labels.push(label);
    }
    let ds = Dataset {
        images,
        labels,
        n: spec.n,
        height: spec.height,
        width: spec.width,
        channels: spec.channels,
        classes: spec.classes,
    };
    debug_assert!(ds.validate().is_ok());
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(&SynthSpec::mnist_like(32), 5);
        let b = generate(&SynthSpec::mnist_like(32), 5);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = generate(&SynthSpec::mnist_like(32), 6);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn split_shares_task_but_not_samples() {
        let spec = SynthSpec::mnist_like(300);
        let train = generate_split(&spec, 5, 5);
        let test = generate_split(&spec, 5, 99);
        // different samples...
        assert_ne!(train.images, test.images);
        // ...but same task: train prototypes classify test samples well.
        let d = spec.height * spec.width * spec.channels;
        let mut protos = vec![0f32; spec.classes * d];
        for k in 0..spec.classes {
            let p = class_prototype(&spec, k, 5);
            render_proto(&p, &spec, &mut protos[k * d..(k + 1) * d]);
        }
        let mut correct = 0usize;
        for i in 0..test.n {
            let img = test.image(i);
            let mut best = (f64::INFINITY, 0usize);
            for k in 0..spec.classes {
                let pr = &protos[k * d..(k + 1) * d];
                let dist: f64 = img
                    .iter()
                    .zip(pr)
                    .map(|(&a, &b)| (a as f64 - (b as f64).clamp(0.0, 1.0)).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, k);
                }
            }
            if best.1 as i32 == test.labels[i] {
                correct += 1;
            }
        }
        assert!(correct as f64 / test.n as f64 > 0.6);
    }

    #[test]
    fn shapes_and_ranges() {
        let ds = generate(&SynthSpec::cifar_like(16), 1);
        assert_eq!(ds.n, 16);
        assert_eq!(ds.sample_elems(), 32 * 32 * 3);
        assert!(ds.validate().is_ok());
        assert!(ds.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn covers_all_classes() {
        let ds = generate(&SynthSpec::mnist_like(2000), 2);
        let counts = ds.class_counts();
        assert!(counts.iter().all(|&c| c > 100), "{counts:?}");
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // nearest-prototype classification on clean prototypes must beat
        // chance by a wide margin, else the task is unlearnable.
        let spec = SynthSpec::mnist_like(500);
        let ds = generate(&spec, 7);
        let d = ds.sample_elems();
        let mut protos = vec![0f32; spec.classes * d];
        for k in 0..spec.classes {
            let p = class_prototype(&spec, k, 7);
            render_proto(&p, &spec, &mut protos[k * d..(k + 1) * d]);
        }
        let mut correct = 0usize;
        for i in 0..ds.n {
            let img = ds.image(i);
            let mut best = (f64::INFINITY, 0usize);
            for k in 0..spec.classes {
                let pr = &protos[k * d..(k + 1) * d];
                let dist: f64 = img
                    .iter()
                    .zip(pr)
                    .map(|(&a, &b)| {
                        let bb = (b as f64).clamp(0.0, 1.0);
                        (a as f64 - bb).powi(2)
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, k);
                }
            }
            if best.1 as i32 == ds.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n as f64;
        assert!(acc > 0.6, "nearest-prototype accuracy {acc}");
    }

    #[test]
    fn label_noise_flips_some() {
        let mut spec = SynthSpec::mnist_like(4000);
        spec.label_noise = 0.5;
        let noisy = generate(&spec, 3);
        spec.label_noise = 0.0;
        let clean = generate(&spec, 3);
        let diffs = noisy
            .labels
            .iter()
            .zip(&clean.labels)
            .filter(|(a, b)| a != b)
            .count();
        // 50% flip to random class ⇒ ≈45% actually differ
        assert!(diffs > 1000, "{diffs}");
    }
}
