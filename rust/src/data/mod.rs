//! Datasets and federated partitioning (substrate).
//!
//! **Substitution note (DESIGN.md §4):** the build environment has no
//! network, so MNIST / CIFAR-10 are replaced by deterministic synthetic
//! generators of identical tensor shape: class-prototype images plus
//! structured noise ([`synth`]). The DEFL experiments measure delay /
//! convergence trade-offs, which require a learnable classification task
//! of the right dimensions, not those exact corpora. If a real
//! `mnist.npz` / `cifar.npz` (keys `x`, `y`) is dropped into `data/`,
//! [`load_npz_dataset`] picks it up instead.
//!
//! Partitioners implement the paper's distributed-data setting: IID
//! shuffle-split (paper's evaluation), Dirichlet(α) label skew and
//! McMahan-style shard splits for the non-IID extension.

/// Deterministic synthetic dataset generators.
pub mod synth;
/// Federated partitioners (IID, Dirichlet, shards).
pub mod partition;

pub use partition::{partition_iid, partition_dirichlet, partition_shards, Partition};
pub use synth::{SynthSpec, generate};

/// A dense image-classification dataset in NHWC f32, labels i32.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Flat NHWC image data.
    pub images: Vec<f32>,
    /// Class label per sample.
    pub labels: Vec<i32>,
    /// Sample count.
    pub n: usize,
    /// Image height.
    pub height: usize,
    /// Image width.
    pub width: usize,
    /// Image channels.
    pub channels: usize,
    /// Distinct class labels.
    pub classes: usize,
}

impl Dataset {
    /// f32 elements per sample (H·W·C).
    pub fn sample_elems(&self) -> usize {
        self.height * self.width * self.channels
    }

    /// Bits per input sample (f32 elements × 32) — the `G_m·b` pricing in
    /// eq. (4) consumes this.
    pub fn bits_per_sample(&self) -> f64 {
        (self.sample_elems() * 32) as f64
    }

    /// Borrow sample `i` as an image slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let d = self.sample_elems();
        &self.images[i * d..(i + 1) * d]
    }

    /// Gather `idx` into a contiguous batch buffer (x, y).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(idx.len() * self.sample_elems());
        let mut y = Vec::with_capacity(idx.len());
        self.gather_into(idx, &mut x, &mut y);
        (x, y)
    }

    /// [`Dataset::gather`] into caller-owned buffers (cleared, then
    /// filled) — the devices' per-round batch planning reuses its buffers
    /// through this, so a warm round loop gathers without allocating.
    pub fn gather_into(&self, idx: &[usize], x: &mut Vec<f32>, y: &mut Vec<i32>) {
        x.clear();
        y.clear();
        x.reserve(idx.len() * self.sample_elems());
        y.reserve(idx.len());
        for &i in idx {
            x.extend_from_slice(self.image(i));
            y.push(self.labels[i]);
        }
    }

    /// Class histogram (used by partition tests and non-IID diagnostics).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }

    /// Check the buffer lengths against the declared dims.
    pub fn validate(&self) -> anyhow::Result<()> {
        let d = self.sample_elems();
        anyhow::ensure!(self.images.len() == self.n * d, "image buffer size");
        anyhow::ensure!(self.labels.len() == self.n, "label count");
        anyhow::ensure!(
            self.labels.iter().all(|&l| (0..self.classes as i32).contains(&l)),
            "label out of range"
        );
        anyhow::ensure!(
            self.images.iter().all(|v| v.is_finite()),
            "non-finite pixel"
        );
        Ok(())
    }
}

/// Load a dataset from an npz with `x: f32 [n,h,w,c]`, `y: i32/i64 [n]`.
/// (npz IO comes from the `xla` crate, so this is `pjrt`-only; the native
/// backend always trains on the synthetic generators.)
#[cfg(feature = "pjrt")]
pub fn load_npz_dataset(path: &std::path::Path, classes: usize) -> anyhow::Result<Dataset> {
    use xla::FromRawBytes;
    let entries: Vec<(String, xla::Literal)> = xla::Literal::read_npz(path, &())?;
    let find = |name: &str| {
        entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, l)| l)
            .ok_or_else(|| anyhow::anyhow!("{} missing key {name}", path.display()))
    };
    let x = find("x")?;
    let y = find("y")?;
    let xs = x.array_shape()?;
    let dims = xs.dims();
    anyhow::ensure!(dims.len() == 4, "x must be [n,h,w,c], got {dims:?}");
    let images = x.to_vec::<f32>()?;
    let labels: Vec<i32> = match y.ty()? {
        xla::ElementType::S32 => y.to_vec::<i32>()?,
        xla::ElementType::S64 => y.to_vec::<i64>()?.into_iter().map(|v| v as i32).collect(),
        other => anyhow::bail!("y dtype {other:?} unsupported"),
    };
    let ds = Dataset {
        n: dims[0] as usize,
        height: dims[1] as usize,
        width: dims[2] as usize,
        channels: dims[3] as usize,
        classes,
        images,
        labels,
    };
    ds.validate()?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        generate(&SynthSpec::mnist_like(64), 1)
    }

    #[test]
    fn gather_shapes() {
        let ds = tiny();
        let (x, y) = ds.gather(&[0, 5, 9]);
        assert_eq!(x.len(), 3 * ds.sample_elems());
        assert_eq!(y.len(), 3);
    }

    #[test]
    fn gather_into_reuses_buffers_and_matches_gather() {
        let ds = tiny();
        let (x, y) = ds.gather(&[1, 2, 3]);
        let mut bx = Vec::new();
        let mut by = Vec::new();
        ds.gather_into(&[7, 8], &mut bx, &mut by); // stale contents…
        ds.gather_into(&[1, 2, 3], &mut bx, &mut by); // …must be replaced
        assert_eq!(bx, x);
        assert_eq!(by, y);
    }

    #[test]
    fn bits_per_sample_mnist() {
        let ds = tiny();
        assert_eq!(ds.bits_per_sample(), (28 * 28 * 32) as f64);
    }

    #[test]
    fn validate_catches_bad_labels() {
        let mut ds = tiny();
        ds.labels[0] = 99;
        assert!(ds.validate().is_err());
    }

    #[test]
    fn validate_catches_nan() {
        let mut ds = tiny();
        ds.images[3] = f32::NAN;
        assert!(ds.validate().is_err());
    }
}
