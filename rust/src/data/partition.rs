//! Federated data partitioners: IID (paper's evaluation), Dirichlet label
//! skew and McMahan shard splits (non-IID extension experiments).

use super::Dataset;
use crate::util::rng::Pcg32;

/// Per-device index sets into a parent [`Dataset`].
#[derive(Clone, Debug)]
pub struct Partition {
    /// Per-device sample indices into the shared corpus.
    pub device_indices: Vec<Vec<usize>>,
}

impl Partition {
    /// Number of shards (devices).
    pub fn num_devices(&self) -> usize {
        self.device_indices.len()
    }

    /// Shard sizes D_m.
    pub fn sizes(&self) -> Vec<usize> {
        self.device_indices.iter().map(|v| v.len()).collect()
    }

    /// Total assigned samples.
    pub fn total(&self) -> usize {
        self.device_indices.iter().map(|v| v.len()).sum()
    }

    /// Every index used at most once across devices?
    pub fn is_disjoint(&self) -> bool {
        let mut seen = std::collections::HashSet::new();
        for dev in &self.device_indices {
            for &i in dev {
                if !seen.insert(i) {
                    return false;
                }
            }
        }
        true
    }

    /// Per-device class histograms (skew diagnostics).
    pub fn class_histograms(&self, ds: &Dataset) -> Vec<Vec<usize>> {
        self.device_indices
            .iter()
            .map(|idx| {
                let mut h = vec![0usize; ds.classes];
                for &i in idx {
                    h[ds.labels[i] as usize] += 1;
                }
                h
            })
            .collect()
    }
}

/// IID: global shuffle, equal contiguous slices (remainder spread over the
/// first devices). This is the paper's "distributed data" setting.
pub fn partition_iid(ds: &Dataset, devices: usize, seed: u64) -> Partition {
    assert!(devices > 0 && devices <= ds.n, "devices {devices} vs n {}", ds.n);
    let mut idx: Vec<usize> = (0..ds.n).collect();
    let mut rng = Pcg32::new(seed, 0x11D);
    rng.shuffle(&mut idx);
    let base = ds.n / devices;
    let extra = ds.n % devices;
    let mut out = Vec::with_capacity(devices);
    let mut pos = 0;
    for d in 0..devices {
        let take = base + usize::from(d < extra);
        out.push(idx[pos..pos + take].to_vec());
        pos += take;
    }
    Partition { device_indices: out }
}

/// Dirichlet(α) label-skew: for each class, split its samples across
/// devices with Dirichlet proportions. Small α ⇒ severe skew.
pub fn partition_dirichlet(ds: &Dataset, devices: usize, alpha: f64, seed: u64) -> Partition {
    assert!(devices > 0 && alpha > 0.0);
    let mut rng = Pcg32::new(seed, 0xD112);
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
    for (i, &l) in ds.labels.iter().enumerate() {
        per_class[l as usize].push(i);
    }
    let mut out = vec![Vec::new(); devices];
    for idxs in per_class.iter_mut() {
        rng.shuffle(idxs);
        let props = rng.dirichlet(&vec![alpha; devices]);
        // proportional integer allocation, remainder to largest shares
        let n = idxs.len();
        let mut counts: Vec<usize> =
            props.iter().map(|p| (p * n as f64).floor() as usize).collect();
        let mut assigned: usize = counts.iter().sum();
        let mut order: Vec<usize> = (0..devices).collect();
        order.sort_by(|&a, &b| props[b].partial_cmp(&props[a]).unwrap());
        let mut oi = 0;
        while assigned < n {
            counts[order[oi % devices]] += 1;
            assigned += 1;
            oi += 1;
        }
        let mut pos = 0;
        for (d, &c) in counts.iter().enumerate() {
            out[d].extend_from_slice(&idxs[pos..pos + c]);
            pos += c;
        }
    }
    Partition { device_indices: out }
}

/// McMahan shards: sort by label, cut into `shards_per_device·devices`
/// shards, deal each device `shards_per_device` random shards — every
/// device sees only a few classes.
pub fn partition_shards(
    ds: &Dataset,
    devices: usize,
    shards_per_device: usize,
    seed: u64,
) -> Partition {
    assert!(devices > 0 && shards_per_device > 0);
    let total_shards = devices * shards_per_device;
    assert!(total_shards <= ds.n, "more shards than samples");
    let mut idx: Vec<usize> = (0..ds.n).collect();
    idx.sort_by_key(|&i| ds.labels[i]);
    let mut shard_ids: Vec<usize> = (0..total_shards).collect();
    let mut rng = Pcg32::new(seed, 0x54A2);
    rng.shuffle(&mut shard_ids);
    let shard_len = ds.n / total_shards;
    let mut out = vec![Vec::new(); devices];
    for (pos, &sid) in shard_ids.iter().enumerate() {
        let dev = pos / shards_per_device;
        let lo = sid * shard_len;
        let hi = if sid == total_shards - 1 { ds.n } else { lo + shard_len };
        out[dev].extend_from_slice(&idx[lo..hi]);
    }
    Partition { device_indices: out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::util::prop;

    fn ds() -> Dataset {
        generate(&SynthSpec::mnist_like(1000), 9)
    }

    #[test]
    fn iid_covers_everything_disjointly() {
        let ds = ds();
        let p = partition_iid(&ds, 10, 1);
        assert_eq!(p.num_devices(), 10);
        assert_eq!(p.total(), 1000);
        assert!(p.is_disjoint());
        assert!(p.sizes().iter().all(|&s| s == 100));
    }

    #[test]
    fn iid_remainder_spread() {
        let ds = ds();
        let p = partition_iid(&ds, 7, 1);
        let sizes = p.sizes();
        assert_eq!(p.total(), 1000);
        assert!(sizes.iter().all(|&s| s == 142 || s == 143), "{sizes:?}");
    }

    #[test]
    fn iid_balanced_classes() {
        let ds = ds();
        let p = partition_iid(&ds, 10, 2);
        for h in p.class_histograms(&ds) {
            // each device should see most classes
            let nonzero = h.iter().filter(|&&c| c > 0).count();
            assert!(nonzero >= 8, "{h:?}");
        }
    }

    #[test]
    fn dirichlet_small_alpha_skews() {
        let ds = ds();
        let p = partition_dirichlet(&ds, 10, 0.1, 3);
        assert_eq!(p.total(), 1000);
        assert!(p.is_disjoint());
        // severe skew: some device has a dominant class > 60% of its data
        let skewed = p.class_histograms(&ds).iter().any(|h| {
            let tot: usize = h.iter().sum();
            tot > 0 && *h.iter().max().unwrap() as f64 / tot as f64 > 0.6
        });
        assert!(skewed);
    }

    #[test]
    fn dirichlet_large_alpha_close_to_uniform() {
        let ds = ds();
        let p = partition_dirichlet(&ds, 5, 1000.0, 3);
        for h in p.class_histograms(&ds) {
            let tot: usize = h.iter().sum();
            let maxfrac = *h.iter().max().unwrap() as f64 / tot as f64;
            assert!(maxfrac < 0.3, "{h:?}");
        }
    }

    #[test]
    fn shards_limit_class_diversity() {
        let ds = ds();
        let p = partition_shards(&ds, 10, 2, 4);
        assert!(p.is_disjoint());
        assert_eq!(p.total(), 1000);
        for h in p.class_histograms(&ds) {
            let nonzero = h.iter().filter(|&&c| c > 0).count();
            assert!(nonzero <= 4, "shard device saw {nonzero} classes: {h:?}");
        }
    }

    #[test]
    fn prop_partitions_disjoint_and_complete() {
        let ds = ds();
        prop::check(0x9A27, 30, |g| {
            let devices = g.usize_in(1, 20);
            let seed = g.rng.next_u64();
            let p = match g.usize_in(0, 2) {
                0 => partition_iid(&ds, devices, seed),
                1 => partition_dirichlet(&ds, devices, g.f64_in(0.05, 10.0), seed),
                _ => partition_shards(&ds, devices, g.usize_in(1, 3), seed),
            };
            if !p.is_disjoint() {
                return Err("overlapping partition".into());
            }
            if p.total() > ds.n {
                return Err("partition larger than dataset".into());
            }
            if p.total() < ds.n - devices * 3 {
                return Err(format!("dropped too many samples: {}", p.total()));
            }
            Ok(())
        });
    }
}
