//! Bench/regeneration target for Fig. 2 (MNIST): DEFL vs FedAvg vs Rand.
//! Scaled-down here; the full comparison is `defl exp fig2 --dataset mnist`.

use defl::experiments::{fig2, ExpOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = ExpOpts::from_env()?;
    opts.fast = true;
    opts.out_dir = "results/bench".into();
    let t0 = std::time::Instant::now();
    fig2::run(&opts, fig2::Which::Mnist)?;
    println!("fig2-mnist (fast) regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
