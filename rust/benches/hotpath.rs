//! Hot-path microbenchmarks: the L3 pieces the round loop spends time in
//! (EXPERIMENTS.md §Perf records these before/after optimization), plus
//! the train/eval step of every compiled backend per batch size.
//!
//! The aggregation benches cover both the allocating `federated_average`
//! (kept for comparison) and the streaming `FedAccumulator` fold the round
//! engines actually run, at 10/100/1000 devices; `native_round_loop_*`
//! times one whole engine round (plan → batched in-place train → delta
//! fold) end to end. `native_train_step_reference_*` keeps the
//! pre-batching per-sample kernel in the suite so the batched speedup is
//! measurable inside a single run.
//!
//! The codec benches cover the compressed-update pipeline end to end:
//! `codec_encode_*` (EF + encode of a 103k-param delta per codec),
//! `codec_fold_{100,1000}dev_{dense,topk10}` (fused decode-and-fold —
//! the top-k variant folds strictly fewer f32s per update), and
//! `native_round_loop_100dev_b8_topk10` (a whole engine round, dense vs
//! top-k comparable against `native_round_loop_100dev_b8`).
//!
//! The SIMD/sharding benches (DESIGN.md §15) pin the kernel-level
//! factors inside one run: `simd_matmul_{scalar,simd}_b64` (lane-blocked
//! vs scalar batch matmul), `quant_unpack_{scalar,simd}` (i16-level vs
//! packed-bitstream dequantize-and-fold of a 100k leaf), and
//! `sharded_fold_{1,4,8}thr_1000dev_{dense,topk10}` (the engines' batch
//! fold sharded by parameter block — bit-identical across the whole
//! grid, only the wall-clock moves).
//!
//! The online-planning benches price the per-round controller/drift
//! additions (DESIGN.md §10): `wireless_drift_step_{10,1000}dev` (walk +
//! Gilbert–Elliott transitions per device) and `controller_replan_*`
//! (EWMA observe + eq. 29 closed-form re-solve vs the deadband skip
//! path — both must stay trivially cheap next to a training round).
//! `coordinator_tick_{100,1000}dev` runs one full churned tick (gate,
//! membership step, engine round, commit — DESIGN.md §11); its delta
//! against `native_round_loop_*dev_b8` is the open-world bookkeeping
//! cost per round. `robust_fold_100dev_{mean,clip,trimmed_mean,median}`
//! prices each robust aggregator (DESIGN.md §13) over the same dense
//! fold: `mean` is the trait-seam control, the buffered estimators show
//! the O(K·P) materialize + sort premium.
//! `transport_uplink_{100,1000}dev` prices one chunked-ARQ uplink round
//! (DESIGN.md §14) at 10% chunk loss — the per-device per-round cost of
//! the erasure/CRC/backoff machinery the engines pay when `[transport]`
//! is on.
//!
//! `DEFL_BENCH_FAST=1` shrinks iteration counts **and** the distinct-set
//! count behind the 1000-device fold (64 sets cycled instead of 1000
//! resident — the fold cost is identical, the setup footprint is not: CI
//! smoke should not allocate 400 MB); `DEFL_BENCH_JSON=path.json`
//! additionally writes the machine-readable report CI uploads and diffs
//! against the committed baseline (tools/bench_diff.py).

use defl::bench::Suite;
use defl::codec::{Dense32, EncodedDelta, QuantStochastic, TopK, TopKQuant, UpdateCodec};
use defl::data::synth::{generate, SynthSpec};
use defl::defl_opt::{self, Controller, ControllerConfig, PlanInputs, RoundObservation};
use defl::model::{federated_average, FedAccumulator, ParamSet};
use defl::util::rng::Pcg32;
use defl::wireless::{Channel, ChannelConfig, TransportConfig};

/// mnist_cnn-ish leaf layout (~103k params).
const LEAVES_103K: [usize; 4] = [100_352, 128, 1_280, 10];

fn random_sets(n: usize, leaves: &[usize], seed: u64) -> Vec<ParamSet> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| ParamSet {
            leaves: leaves
                .iter()
                .map(|&len| (0..len).map(|_| rng.uniform() as f32).collect())
                .collect(),
        })
        .collect()
}

fn fast_mode() -> bool {
    std::env::var("DEFL_BENCH_FAST").as_deref() == Ok("1")
}

fn main() -> anyhow::Result<()> {
    let mut suite = Suite::new("hotpath");
    let total_params: usize = LEAVES_103K.iter().sum();

    // --- aggregation (the L3 CPU hot spot) ---------------------------
    let sets = random_sets(10, &LEAVES_103K, 1);
    let weights = vec![600.0; 10];
    // Hoisted out of the timed closure: the old bench rebuilt this ref
    // vec per iteration and so timed an allocation alongside the fold.
    let refs: Vec<&ParamSet> = sets.iter().collect();
    suite.bench_units("fedavg_10dev_103k", (10 * total_params) as f64, || {
        federated_average(&refs, &weights)
    });

    // The engines' path: stream weighted deltas into a preallocated
    // accumulator and apply to a resident global — zero allocation per
    // round at any fleet size.
    let mut global = ParamSet::zeros_matching(&sets[0]);
    let mut acc = FedAccumulator::zeros_like(&sets[0]);
    for (devices, label) in [
        (10usize, "fedavg_stream_10dev_103k"),
        (100, "fedavg_stream_100dev_103k"),
        (1000, "fedavg_stream_1000dev_103k"),
    ] {
        // Distinct resident sets: full count normally (honest memory
        // traffic), capped in CI smoke to bound the footprint.
        let distinct = if fast_mode() { devices.min(64) } else { devices };
        let pool = random_sets(distinct, &LEAVES_103K, 2 + devices as u64);
        suite.bench_units(label, (devices * total_params) as f64, || {
            acc.begin(600.0 * devices as f64);
            for i in 0..devices {
                acc.fold(600.0, &pool[i % distinct]);
            }
            acc.apply_delta_to(&mut global);
            acc.count()
        });
    }

    // --- codec encode + fused decode-and-fold ------------------------
    // Encode: EF-in + select/quantize + buffer write of one 103k-param
    // delta. Each iteration restores the delta from a pristine copy —
    // encode mutates it in place (EF-in), and re-feeding the mutated
    // delta would compound the residual without bound. The restoring
    // memcpy mirrors the real round's pull-global copy, and the
    // persistent residual reaches its EF steady state, like the round
    // loop's. Warm iterations are allocation-free.
    let codecs: Vec<(&str, Box<dyn UpdateCodec>)> = vec![
        ("dense", Box::new(Dense32)),
        ("quant8", Box::new(QuantStochastic { qbits: 8 })),
        ("topk10", Box::new(TopK { k_ratio: 0.1 })),
        ("topkq8", Box::new(TopKQuant { k_ratio: 0.1, qbits: 8 })),
    ];
    let mut enc_rng = Pcg32::seeded(11);
    for (name, codec) in &codecs {
        let pristine = random_sets(1, &LEAVES_103K, 40).pop().unwrap();
        let mut delta = pristine.clone();
        let mut residual = ParamSet::zeros_matching(&delta);
        let mut enc = EncodedDelta::new();
        suite.bench_units(&format!("codec_encode_{name}_103k"), total_params as f64, || {
            delta.copy_from(&pristine);
            let res = if codec.lossy() { Some(&mut residual) } else { None };
            codec.encode(&mut delta, res, &mut enc_rng, &mut enc);
            enc.folded_values()
        });
    }

    // Fused decode-and-fold at fleet scale: the engines' aggregation
    // path. Dense folds devices×103k f32s; topk at k_ratio=0.1 folds
    // strictly fewer (~10%) — the unit counts make the per-value and
    // per-round wins separately visible in the report.
    for devices in [100usize, 1000] {
        for (name, codec) in &codecs {
            if *name == "quant8" || *name == "topkq8" {
                continue; // dense-vs-topk is the headline; keep the suite lean
            }
            let distinct = if fast_mode() { devices.min(64) } else { devices };
            let mut pool_rng = Pcg32::seeded(60 + devices as u64);
            let mut encs: Vec<EncodedDelta> = Vec::with_capacity(distinct);
            for set in random_sets(distinct, &LEAVES_103K, 50 + devices as u64) {
                let mut delta = set;
                let mut residual = ParamSet::zeros_matching(&delta);
                let mut enc = EncodedDelta::new();
                let res = if codec.lossy() { Some(&mut residual) } else { None };
                codec.encode(&mut delta, res, &mut pool_rng, &mut enc);
                encs.push(enc);
            }
            let folded: usize = encs[0].folded_values();
            let mut acc = FedAccumulator::zeros_like(&sets[0]);
            let mut fold_global = ParamSet::zeros_matching(&sets[0]);
            let label = format!("codec_fold_{devices}dev_{name}");
            suite.bench_units(&label, (devices * folded) as f64, || {
                acc.begin(600.0 * devices as f64);
                for i in 0..devices {
                    codec.decode_fold_into(&mut acc, 600.0, &encs[i % distinct]);
                }
                acc.apply_delta_to(&mut fold_global);
                acc.count()
            });
        }
    }

    // --- SIMD kernels: scalar vs lane-blocked (DESIGN.md §15) ---------
    // Same inputs, same outputs (bit-identical — pinned by
    // rust/tests/kernels_diff.rs); the pair quantifies the lane-blocking
    // win on a softmax-step-shaped matmul and a 100k quant decode.
    {
        use defl::runtime::kernels;
        let (n, d, k) = (64usize, 256, 32);
        let mut rng = Pcg32::seeded(21);
        let x: Vec<f32> = (0..n * d).map(|_| rng.uniform() as f32).collect();
        let w: Vec<f32> = (0..d * k).map(|_| rng.uniform() as f32).collect();
        let bias: Vec<f32> = (0..k).map(|_| rng.uniform() as f32).collect();
        let mut out = vec![0f32; n * k];
        suite.bench_units("simd_matmul_scalar_b64", (n * d * k) as f64, || {
            kernels::matmul_bias(&x, &w, &bias, &mut out, n, d, k);
            out[0]
        });
        suite.bench_units("simd_matmul_simd_b64", (n * d * k) as f64, || {
            kernels::simd::matmul_bias(&x, &w, &bias, &mut out, n, d, k);
            out[0]
        });

        // fused dequantize-and-fold of one 100k leaf at qbits=8: i16
        // levels (scalar) vs the packed wire bitstream (word-at-a-time)
        let len = 100_352usize;
        let src: Vec<f32> = (0..len).map(|_| rng.uniform() as f32 - 0.5).collect();
        let mut q = Vec::new();
        let scale = kernels::quantize_stochastic(&src, 8, &mut rng, &mut q);
        let mut packed = Vec::new();
        kernels::pack_levels(&q, 8, &mut packed);
        let mut dst = vec![0f32; len];
        suite.bench_units("quant_unpack_scalar", len as f64, || {
            kernels::axpy_quant(0.25, &q, scale, &mut dst);
            dst[0]
        });
        suite.bench_units("quant_unpack_simd", len as f64, || {
            kernels::simd::axpy_quant_packed(0.25, &packed, 8, scale, &mut dst);
            dst[0]
        });
    }

    // --- sharded parallel fold (DESIGN.md §15) ------------------------
    // The engines' batch fold at 1000 devices across 1/4/8 threads,
    // dense and top-k encoded. The shard contract makes every cell of
    // the grid bit-identical; the thread axis should only move time.
    {
        use defl::model::FoldPayload;
        let devices = 1000usize;
        let distinct = if fast_mode() { 64 } else { devices };
        let pool = random_sets(distinct, &LEAVES_103K, 91);
        let topk = TopK { k_ratio: 0.1 };
        let mut enc_pool: Vec<EncodedDelta> = Vec::with_capacity(distinct);
        let mut rng = Pcg32::seeded(92);
        for set in random_sets(distinct, &LEAVES_103K, 93) {
            let mut delta = set;
            let mut residual = ParamSet::zeros_matching(&delta);
            let mut enc = EncodedDelta::new();
            topk.encode(&mut delta, Some(&mut residual), &mut rng, &mut enc);
            enc_pool.push(enc);
        }
        let folded = enc_pool[0].folded_values();
        let mut acc = FedAccumulator::zeros_like(&pool[0]);
        let mut g = ParamSet::zeros_matching(&pool[0]);
        for threads in [1usize, 4, 8] {
            let dense_batch: Vec<(f64, FoldPayload<'_>)> = (0..devices)
                .map(|i| (600.0, FoldPayload::Dense(&pool[i % distinct])))
                .collect();
            suite.bench_units(
                &format!("sharded_fold_{threads}thr_1000dev_dense"),
                (devices * total_params) as f64,
                || {
                    acc.begin(600.0 * devices as f64);
                    acc.fold_batch(&dense_batch, threads);
                    acc.apply_delta_to(&mut g);
                    acc.count()
                },
            );
            let topk_batch: Vec<(f64, FoldPayload<'_>)> = (0..devices)
                .map(|i| (600.0, FoldPayload::Encoded(&enc_pool[i % distinct])))
                .collect();
            suite.bench_units(
                &format!("sharded_fold_{threads}thr_1000dev_topk10"),
                (devices * folded) as f64,
                || {
                    acc.begin(600.0 * devices as f64);
                    acc.fold_batch(&topk_batch, threads);
                    acc.apply_delta_to(&mut g);
                    acc.count()
                },
            );
        }
    }

    // --- robust aggregation (DESIGN.md §13) ---------------------------
    // The per-round cost of each RobustAggregator over 100 dense 103k
    // updates. `mean` prices the trait seam itself (same work as
    // fedavg_stream_100dev_103k); `clip` adds the norms pass; the
    // buffered estimators pay the O(K·P) materialize + per-coordinate
    // sort that bounds their use to modest cohort sizes.
    {
        use defl::model::robust::{AggKind, AggregateConfig, RoundUpdate};
        let devices = 100usize;
        let distinct = if fast_mode() { 64 } else { devices };
        let pool = random_sets(distinct, &LEAVES_103K, 77);
        let codec = Dense32;
        for kind in [AggKind::Mean, AggKind::Clip, AggKind::TrimmedMean, AggKind::Median] {
            let cfg = AggregateConfig { kind, ..Default::default() };
            let mut robust = cfg.build()?;
            let mut acc = FedAccumulator::zeros_like(&pool[0]);
            let mut g = ParamSet::zeros_matching(&pool[0]);
            let updates: Vec<RoundUpdate<'_>> = (0..devices)
                .map(|i| RoundUpdate {
                    weight: 600.0,
                    dense: Some(&pool[i % distinct]),
                    encoded: None,
                    attacked: false,
                })
                .collect();
            let label = format!("robust_fold_{devices}dev_{}", kind.label());
            suite.bench_units(&label, (devices * total_params) as f64, || {
                robust.combine(&codec, &mut acc, &updates, 600.0 * devices as f64, 1, &mut g);
                acc.count()
            });
        }
    }

    // --- channel sampling --------------------------------------------
    let mut channel = Channel::new(ChannelConfig::default(), 10, 3);
    suite.bench("channel_round_10dev", || channel.round(3.3e6));

    // --- channel drift (the per-round [drift] step) -------------------
    // Walk + Gilbert–Elliott on, so the bench prices the full step (the
    // disabled path is a branch and costs nothing).
    for devices in [10usize, 1000] {
        let mut cfg = ChannelConfig::default();
        cfg.drift.walk_db = 1.0;
        cfg.drift.ge_p_bad = 0.05;
        cfg.drift.ge_p_good = 0.25;
        let mut ch = Channel::new(cfg, devices, 9);
        suite.bench_units(&format!("wireless_drift_step_{devices}dev"), devices as f64, || {
            ch.step_drift();
            ch.drift_db(0)
        });
    }

    // --- transport ARQ (the per-round unreliable-uplink machinery) ----
    // 10% chunk loss over 5 chunks per 77k-bit update plus the CRC
    // trickle: every device pays the full chunk/erasure/backoff path
    // (DESIGN.md §14), so the bench prices the worst realistic case the
    // engines run per round. Off is a branch and costs nothing.
    for devices in [100usize, 1000] {
        let mut ch = Channel::new(ChannelConfig::default(), devices, 9);
        let mut t = TransportConfig::default();
        t.chunk_bits = 16_384.0;
        t.chunk_loss_prob = 0.1;
        t.corrupt_prob = 0.002;
        t.ack_timeout_s = 0.005;
        t.backoff_base_s = 0.002;
        t.backoff_cap_s = 0.02;
        let mut rng = Pcg32::new(9 ^ 0x7A27, 0x7A27);
        suite.bench_units(&format!("transport_uplink_{devices}dev"), devices as f64, || {
            let (_, t_cm, _, stats) = ch.round_with_transport(77_120.0, &t, &mut rng);
            (t_cm, stats.retransmits)
        });
    }

    // --- online controller (observe + re-solve eq. 29 per round) ------
    // A slow geometric drift on the observed T_cm keeps the estimator
    // moving; deadband 0 forces a closed-form re-solve every call.
    {
        let inputs = PlanInputs::default();
        let plan = defl_opt::closed_form(&inputs);
        let cfg = ControllerConfig { replan_every: 1, ewma: 0.3, max_step: 1.0, deadband: 0.0 };
        let mut ctl = Controller::new(cfg, inputs, plan);
        let mut t = inputs.t_cm;
        suite.bench("controller_replan_every1", || {
            t *= 0.999;
            ctl.observe(&RoundObservation {
                t_cm: t,
                t_cp_per_sample: inputs.t_cp_per_sample,
                train_loss: 1.0,
            });
            ctl.maybe_replan().map(|p| p.batch)
        });
        // the hysteresis fast path: a wide deadband skips the re-solve
        let cfg = ControllerConfig { replan_every: 1, ewma: 0.3, max_step: 1.0, deadband: 1e6 };
        let mut ctl = Controller::new(cfg, inputs, plan);
        let mut t = inputs.t_cm;
        suite.bench("controller_replan_deadband_skip", || {
            t *= 0.999;
            ctl.observe(&RoundObservation {
                t_cm: t,
                t_cp_per_sample: inputs.t_cp_per_sample,
                train_loss: 1.0,
            });
            ctl.maybe_replan().is_none()
        });
    }

    // --- data synthesis + gather --------------------------------------
    suite.bench("synth_mnist_1k", || generate(&SynthSpec::mnist_like(1000), 7));
    let ds = generate(&SynthSpec::mnist_like(4096), 7);
    let idx: Vec<usize> = (0..64).collect();
    suite.bench_units("gather_b64", 64.0, || ds.gather(&idx));

    // --- trial runner (DESIGN.md §12) ----------------------------------
    // Grid expansion must stay negligible next to the trials it feeds:
    // ci_matrix is the largest committed spec (36 variants × 6 seeds).
    let matrix = defl::harness::specs::load("ci_matrix")?;
    suite.bench_units("trial_runner_expand", 216.0, || matrix.expand(42).unwrap());
    #[cfg(feature = "native")]
    trial_runner_benches(&mut suite)?;

    // --- native backend steps + whole-round loop (no artifacts needed) --
    #[cfg(feature = "native")]
    native_benches(&mut suite)?;

    // --- PJRT execute path (needs artifacts) ---------------------------
    #[cfg(feature = "pjrt")]
    pjrt_benches(&mut suite)?;

    println!("{}", suite.render());
    if let Some(path) = suite.write_json_env()? {
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// One `run_spec` sweep of 100 seeded smoke-scale trials through the
/// thread pool — the end-to-end cost of `defl run` minus the figure
/// formatting, sized so per-trial runner overhead (config build, seed
/// derivation, result marshalling) would show up against the training.
#[cfg(feature = "native")]
fn trial_runner_benches(suite: &mut Suite) -> anyhow::Result<()> {
    use defl::harness::{run_spec, ExperimentSpec, RunnerOpts};

    let spec = ExperimentSpec::from_toml_text(
        r#"
name = "bench-100"
output = "bench_100"

[trials]
seeds = 50
base_seed = 7

[base]
backend.kind = "native"
dataset.kind = "tiny"
dataset.train_per_device = 16
dataset.test_size = 32
system.devices = 2
run.max_rounds = 2
run.eval_every = 2
policy.kind = "fixed"
policy.batch = 8
policy.local_rounds = 2

[[variants]]
name = "sync"
engine.kind = "sync"

[[variants]]
name = "async"
engine.kind = "async_buffered"
"#,
    )?;
    let mut opts = RunnerOpts::default();
    opts.write_trials = false; // time the runner, not the filesystem
    suite.bench_units("trial_runner_100trials", 100.0, || run_spec(&spec, &opts).unwrap());
    Ok(())
}

#[cfg(feature = "native")]
fn native_benches(suite: &mut Suite) -> anyhow::Result<()> {
    use defl::config::{DatasetKind, ExperimentConfig, Policy};
    use defl::coordinator::FlSystem;
    use defl::runtime::{BackendKind, NativeBackend, ParallelStep, TrainBackend};

    let mut be = NativeBackend::new(5);
    for (model, spec_fn) in [
        ("mlp", SynthSpec::tiny as fn(usize) -> SynthSpec),
        ("mnist_cnn", SynthSpec::mnist_like as fn(usize) -> SynthSpec),
    ] {
        let params = be.initial_params(model)?;
        for b in [16usize, 64] {
            let tds = generate(&spec_fn(b), 5);
            let idx: Vec<usize> = (0..b).collect();
            let (x, y) = tds.gather(&idx);
            suite.bench_units(&format!("native_train_step_{model}_b{b}"), b as f64, || {
                be.train_step(model, b, &params, &x, &y, 0.01).unwrap()
            });
            // the engines' path: in-place batched step through a reusable
            // scratch — no output clone, no allocation after warmup
            let mut scratch = ParallelStep::new_scratch(&be, model, b)?;
            let mut live = params.clone();
            let name = format!("native_train_step_inplace_{model}_b{b}");
            suite.bench_units(&name, b as f64, || {
                be.train_step_in_place(model, b, &mut live, &x, &y, 0.01, &mut *scratch)
                    .unwrap()
            });
            // the pre-batching per-sample kernel, for the before/after
            // factor inside one run
            suite.bench_units(&format!("native_train_step_reference_{model}_b{b}"), b as f64, || {
                be.train_step_reference(model, b, &params, &x, &y, 0.01).unwrap()
            });
        }
        let eds = generate(&spec_fn(256), 6);
        let idx: Vec<usize> = (0..256).collect();
        let (ex, ey) = eds.gather(&idx);
        suite.bench_units(&format!("native_eval_step_{model}_b256"), 256.0, || {
            be.eval_step(model, 256, &params, &ex, &ey).unwrap()
        });
    }

    // Whole-round-loop benches: one engine round end to end — cohort
    // selection, fan-out plan + batched in-place training, uplink draw,
    // streaming delta fold — at 100 and 1000 devices, plus a top-k
    // variant at 100 devices (dense vs sparse fold, same round anatomy).
    let round_cfg = |devices: usize| {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("bench-round-{devices}");
        cfg.dataset = DatasetKind::Tiny;
        cfg.devices = devices;
        cfg.train_per_device = 8;
        cfg.test_size = 64;
        cfg.max_rounds = 1;
        cfg.policy = Policy::Fixed { batch: 8, local_rounds: 1 };
        cfg.threads = 4;
        cfg.seed = 7;
        cfg.backend = BackendKind::Native;
        cfg.artifacts_dir = "/nonexistent-on-purpose".into();
        cfg
    };
    for devices in [100usize, 1000] {
        let mut sys = FlSystem::build(round_cfg(devices))?;
        suite.bench_units(&format!("native_round_loop_{devices}dev_b8"), devices as f64, || {
            sys.round().unwrap()
        });
    }
    {
        use defl::codec::CodecKind;
        let mut cfg = round_cfg(100);
        cfg.name = "bench-round-100-topk".into();
        cfg.codec.kind = CodecKind::TopK;
        cfg.codec.k_ratio = 0.1;
        let mut sys = FlSystem::build(cfg)?;
        suite.bench_units("native_round_loop_100dev_b8_topk10", 100.0, || sys.round().unwrap());
    }

    // Tick-machine overhead under churn (DESIGN.md §11): one full tick —
    // gate check, round-start churn step, engine round over the live
    // view, aggregate commit — on an open-world fleet. Comparable against
    // native_round_loop_*dev_b8 above: the delta is what membership
    // bookkeeping costs per round.
    for devices in [100usize, 1000] {
        use defl::coordinator::ChurnKind;
        let mut cfg = round_cfg(devices);
        cfg.name = format!("bench-tick-{devices}");
        cfg.churn.kind = ChurnKind::Poisson;
        cfg.churn.initial_active = 0.8;
        cfg.churn.join_rate = 0.3;
        cfg.churn.drop_rate = 0.3;
        cfg.churn.min_clients = 1;
        let mut sys = FlSystem::build(cfg)?;
        suite.bench_units(&format!("coordinator_tick_{devices}dev"), devices as f64, || {
            sys.tick().unwrap().record.is_some()
        });
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_benches(suite: &mut Suite) -> anyhow::Result<()> {
    use defl::runtime::Runtime;
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts missing — PJRT benches skipped (run `make artifacts`)");
        return Ok(());
    }
    let mut rt = Runtime::new("artifacts")?;
    for model in ["mlp", "mnist_cnn"] {
        let params = rt.initial_params(model)?;
        let spec = rt.spec(model)?.clone();
        let elems = spec.height * spec.width * spec.channels;
        for &b in rt.train_batches(model)?.iter() {
            let tds = generate(
                &SynthSpec {
                    n: b.max(1),
                    height: spec.height,
                    width: spec.width,
                    channels: spec.channels,
                    classes: spec.classes,
                    noise: 0.1,
                    label_noise: 0.0,
                    modes: 3,
                },
                5,
            );
            let idx: Vec<usize> = (0..b).collect();
            let (x, y) = tds.gather(&idx);
            assert_eq!(x.len(), b * elems);
            rt.preload(model, &[b])?;
            suite.bench_units(&format!("train_step_{model}_b{b}"), b as f64, || {
                rt.train_step(model, b, &params, &x, &y, 0.01).unwrap()
            });
            // marshalling-only share: literal construction for the
            // same call, no execute (perf-pass diagnostics)
            if b == 32 || model == "mlp" {
                suite.bench(&format!("marshal_only_{model}_b{b}"), || {
                    defl::runtime::marshal_probe(&rt, model, b, &params, &x, &y).unwrap()
                });
            }
        }
    }
    Ok(())
}
