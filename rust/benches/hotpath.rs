//! Hot-path microbenchmarks: the L3 pieces the round loop spends time in
//! (EXPERIMENTS.md §Perf records these before/after optimization), plus
//! the train/eval step of every compiled backend per batch size.
//!
//! `DEFL_BENCH_FAST=1` shrinks iteration counts (the CI smoke lane);
//! `DEFL_BENCH_JSON=path.json` additionally writes the machine-readable
//! report CI uploads as the perf-trajectory artifact.

use defl::bench::Suite;
use defl::data::synth::{generate, SynthSpec};
use defl::model::{federated_average, ParamSet};
use defl::util::rng::Pcg32;
use defl::wireless::{Channel, ChannelConfig};

fn main() -> anyhow::Result<()> {
    let mut suite = Suite::new("hotpath");

    // --- aggregation (the L3 CPU hot spot) ---------------------------
    let leaves: Vec<usize> = vec![100_352, 128, 1_280, 10]; // mnist_cnn-ish
    let mut rng = Pcg32::seeded(1);
    let sets: Vec<ParamSet> = (0..10)
        .map(|_| ParamSet {
            leaves: leaves
                .iter()
                .map(|&n| (0..n).map(|_| rng.uniform() as f32).collect())
                .collect(),
        })
        .collect();
    let weights = vec![600.0; 10];
    let total_params: usize = leaves.iter().sum();
    suite.bench_units("fedavg_10dev_103k", (10 * total_params) as f64, || {
        let refs: Vec<&ParamSet> = sets.iter().collect();
        federated_average(&refs, &weights)
    });

    // --- channel sampling --------------------------------------------
    let mut channel = Channel::new(ChannelConfig::default(), 10, 3);
    suite.bench("channel_round_10dev", || channel.round(3.3e6));

    // --- data synthesis + gather --------------------------------------
    suite.bench("synth_mnist_1k", || generate(&SynthSpec::mnist_like(1000), 7));
    let ds = generate(&SynthSpec::mnist_like(4096), 7);
    let idx: Vec<usize> = (0..64).collect();
    suite.bench_units("gather_b64", 64.0, || ds.gather(&idx));

    // --- native backend steps (no artifacts needed) --------------------
    #[cfg(feature = "native")]
    native_benches(&mut suite)?;

    // --- PJRT execute path (needs artifacts) ---------------------------
    #[cfg(feature = "pjrt")]
    pjrt_benches(&mut suite)?;

    println!("{}", suite.render());
    if let Some(path) = suite.write_json_env()? {
        eprintln!("wrote {path}");
    }
    Ok(())
}

#[cfg(feature = "native")]
fn native_benches(suite: &mut Suite) -> anyhow::Result<()> {
    use defl::runtime::{NativeBackend, TrainBackend};
    let mut be = NativeBackend::new(5);
    for (model, spec_fn) in [
        ("mlp", SynthSpec::tiny as fn(usize) -> SynthSpec),
        ("mnist_cnn", SynthSpec::mnist_like as fn(usize) -> SynthSpec),
    ] {
        let params = be.initial_params(model)?;
        for b in [16usize, 64] {
            let tds = generate(&spec_fn(b), 5);
            let idx: Vec<usize> = (0..b).collect();
            let (x, y) = tds.gather(&idx);
            suite.bench_units(&format!("native_train_step_{model}_b{b}"), b as f64, || {
                be.train_step(model, b, &params, &x, &y, 0.01).unwrap()
            });
        }
        let eds = generate(&spec_fn(256), 6);
        let idx: Vec<usize> = (0..256).collect();
        let (ex, ey) = eds.gather(&idx);
        suite.bench_units(&format!("native_eval_step_{model}_b256"), 256.0, || {
            be.eval_step(model, 256, &params, &ex, &ey).unwrap()
        });
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_benches(suite: &mut Suite) -> anyhow::Result<()> {
    use defl::runtime::Runtime;
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts missing — PJRT benches skipped (run `make artifacts`)");
        return Ok(());
    }
    let mut rt = Runtime::new("artifacts")?;
    for model in ["mlp", "mnist_cnn"] {
        let params = rt.initial_params(model)?;
        let spec = rt.spec(model)?.clone();
        let elems = spec.height * spec.width * spec.channels;
        for &b in rt.train_batches(model)?.iter() {
            let tds = generate(
                &SynthSpec {
                    n: b.max(1),
                    height: spec.height,
                    width: spec.width,
                    channels: spec.channels,
                    classes: spec.classes,
                    noise: 0.1,
                    label_noise: 0.0,
                    modes: 3,
                },
                5,
            );
            let idx: Vec<usize> = (0..b).collect();
            let (x, y) = tds.gather(&idx);
            assert_eq!(x.len(), b * elems);
            rt.preload(model, &[b])?;
            suite.bench_units(&format!("train_step_{model}_b{b}"), b as f64, || {
                rt.train_step(model, b, &params, &x, &y, 0.01).unwrap()
            });
            // marshalling-only share: literal construction for the
            // same call, no execute (perf-pass diagnostics)
            if b == 32 || model == "mlp" {
                suite.bench(&format!("marshal_only_{model}_b{b}"), || {
                    defl::runtime::marshal_probe(&rt, model, b, &params, &x, &y).unwrap()
                });
            }
        }
    }
    Ok(())
}
