//! Bench/regeneration target for Fig. 1(d): rounds H and the
//! compute/communication split vs θ (fully analytic — fast).

use defl::experiments::{fig1d, ExpOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = ExpOpts::from_env()?;
    opts.fast = true;
    opts.out_dir = "results/bench".into();
    fig1d::run(&opts)?;
    Ok(())
}
