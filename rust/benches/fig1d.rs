//! Bench/regeneration target for Fig. 1(d): rounds H and the
//! compute/communication split vs θ (fully analytic — fast).

use defl::experiments::fig1d;
use defl::harness::{specs, RunnerOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = RunnerOpts::from_env()?;
    opts.exp.fast = true;
    opts.exp.out_dir = "results/bench".into();
    fig1d::render(&specs::load("fig1d")?, &opts)?;
    Ok(())
}
