//! Bench/regeneration target for Fig. 1(a): the ε sweep.
//!
//! Prints the paper-style table (analytic closed-form plans; training runs
//! are exercised by `defl exp fig1a`) and benches the optimizer itself.

use defl::bench::Suite;
use defl::defl_opt::{self, PlanInputs};
use defl::experiments::{fig1a, ExpOpts};

fn main() -> anyhow::Result<()> {
    // regenerate the figure's series (analytic mode: no training)
    let mut opts = ExpOpts::from_env()?;
    opts.fast = true;
    opts.out_dir = "results/bench".into();
    fig1a::run(&opts, true)?;

    // bench the solvers the figure is built from
    let mut suite = Suite::new("fig1a: eq.(29) + exact search");
    let inputs = PlanInputs::default();
    suite.bench("closed_form", || defl_opt::closed_form(&inputs));
    suite.bench("numeric_cap64", || defl_opt::numeric(&inputs, 64));
    suite.bench("numeric_cap256", || defl_opt::numeric(&inputs, 256));
    println!("{}", suite.render());
    Ok(())
}
