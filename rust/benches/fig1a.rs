//! Bench/regeneration target for Fig. 1(a): the ε sweep.
//!
//! Prints the paper-style table (analytic closed-form plans; training runs
//! are exercised by `defl run --spec specs/fig1a.toml`) and benches the
//! optimizer itself.

use defl::bench::Suite;
use defl::defl_opt::{self, PlanInputs};
use defl::experiments::fig1a;
use defl::harness::{specs, RunnerOpts};

fn main() -> anyhow::Result<()> {
    // regenerate the figure's series (analytic mode: no training)
    let mut opts = RunnerOpts::from_env()?;
    opts.exp.fast = true;
    opts.exp.out_dir = "results/bench".into();
    opts.analytic_only = true;
    fig1a::render(&specs::load("fig1a")?, &opts)?;

    // bench the solvers the figure is built from
    let mut suite = Suite::new("fig1a: eq.(29) + exact search");
    let inputs = PlanInputs::default();
    suite.bench("closed_form", || defl_opt::closed_form(&inputs));
    suite.bench("numeric_cap64", || defl_opt::numeric(&inputs, 64));
    suite.bench("numeric_cap256", || defl_opt::numeric(&inputs, 256));
    println!("{}", suite.render());
    Ok(())
}
