//! Bench/regeneration target for Fig. 1(b): batch-size sweep (scaled-down
//! training runs; the full figure comes from `defl run --spec specs/fig1b.toml`).

use defl::experiments::fig1b;
use defl::harness::{specs, RunnerOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = RunnerOpts::from_env()?;
    opts.exp.fast = true; // bench context: smoke scale
    opts.exp.out_dir = "results/bench".into();
    let t0 = std::time::Instant::now();
    fig1b::render(&specs::load("fig1b")?, &opts)?;
    println!("fig1b (fast) regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
