//! Bench/regeneration target for Fig. 1(b): batch-size sweep (scaled-down
//! training runs; the full figure comes from `defl exp fig1b`).

use defl::experiments::{fig1b, ExpOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = ExpOpts::from_env()?;
    opts.fast = true; // bench context: smoke scale
    opts.out_dir = "results/bench".into();
    let t0 = std::time::Instant::now();
    fig1b::run(&opts)?;
    println!("fig1b (fast) regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
