//! Bench/regeneration target for Fig. 2 (CIFAR-10): DEFL vs FedAvg vs
//! Rand. Scaled-down; full run: `defl run --spec specs/fig2_cifar.toml`.

use defl::experiments::fig2;
use defl::harness::{specs, RunnerOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = RunnerOpts::from_env()?;
    opts.exp.fast = true;
    opts.exp.out_dir = "results/bench".into();
    let t0 = std::time::Instant::now();
    fig2::render(&specs::load("fig2_cifar")?, &opts)?;
    println!("fig2-cifar (fast) regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
