//! Bench/regeneration target for Fig. 1(c): θ sweep (scaled-down training
//! runs; the full figure comes from `defl exp fig1c`).

use defl::experiments::{fig1c, ExpOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = ExpOpts::from_env()?;
    opts.fast = true;
    opts.out_dir = "results/bench".into();
    let t0 = std::time::Instant::now();
    fig1c::run(&opts)?;
    println!("fig1c (fast) regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
