//! Bench/regeneration target for Fig. 1(c): θ sweep (scaled-down training
//! runs; the full figure comes from `defl run --spec specs/fig1c.toml`).

use defl::experiments::fig1c;
use defl::harness::{specs, RunnerOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = RunnerOpts::from_env()?;
    opts.exp.fast = true;
    opts.exp.out_dir = "results/bench".into();
    let t0 = std::time::Instant::now();
    fig1c::render(&specs::load("fig1c")?, &opts)?;
    println!("fig1c (fast) regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
