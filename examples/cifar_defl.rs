//! CIFAR-10 scenario (Fig. 2 right): DEFL vs Rand. on the harder task.
//!
//! ```sh
//! cargo run --release --example cifar_defl
//! DEFL_FAST=1 cargo run --release --example cifar_defl   # smoke
//! ```

use defl::config::{presets, Policy};
use defl::coordinator::FlSystem;
use defl::experiments::reduction_pct;
use defl::metrics::Table;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DEFL_FAST").as_deref() == Ok("1");
    let mut results = Vec::new();
    for (label, policy) in [
        ("DEFL", Policy::Defl),
        ("Rand.", presets::rand_cifar()),
    ] {
        let mut cfg = presets::fig2_cifar(policy);
        cfg.name = format!("example-cifar-{label}");
        cfg.out = Some(format!("results/example_cifar_{label}.json"));
        if fast {
            cfg.max_rounds = 2;
            cfg.train_per_device = 64;
            cfg.test_size = 256;
            cfg.eval_every = 2;
        }
        let mut sys = FlSystem::build(cfg)?;
        let outcome = sys.run()?;
        results.push((label, outcome));
    }

    let defl_time = results[0].1.overall_time;
    let mut table = Table::new(&["method", "rounds", "overall 𝒯 (s)", "accuracy", "reduction"]);
    for (label, outcome) in &results {
        table.row(&[
            label.to_string(),
            outcome.rounds.to_string(),
            format!("{:.1}", outcome.overall_time),
            format!("{:.4}", outcome.final_test_accuracy),
            if *label == "DEFL" {
                "-".into()
            } else {
                format!("{:.0}%", reduction_pct(defl_time, outcome.overall_time))
            },
        ]);
    }
    println!("\nCIFAR-10 (paper Fig. 2 right; paper reports ≈75% reduction vs Rand.):");
    println!("{}", table.render());
    Ok(())
}
