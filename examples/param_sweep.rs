//! Parameter-sweep scenario: regenerates the analytic parts of Fig. 1
//! (ε sweep, θ sweep, H/compute-share split) without any training —
//! useful for exploring the delay model interactively.
//!
//! ```sh
//! cargo run --release --example param_sweep -- [--devices 10] [--epsilon 0.01]
//! ```

use defl::convergence;
use defl::defl_opt::{self, PlanInputs};
use defl::metrics::Table;
use defl::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("param_sweep", "analytic DEFL parameter exploration")
        .opt("devices", "10", "number of devices M")
        .opt("epsilon", "0.01", "global convergence error ε")
        .opt("t-cm", "0.094", "expected uplink time T_cm (s)")
        .opt("t-cps", "3.763e-4", "bottleneck compute seconds/sample");
    let args = cli
        .parse(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let base = PlanInputs {
        t_cm: args.f64("t-cm").map_err(|e| anyhow::anyhow!("{e}"))?,
        t_cp_per_sample: args.f64("t-cps").map_err(|e| anyhow::anyhow!("{e}"))?,
        m: args.usize("devices").map_err(|e| anyhow::anyhow!("{e}"))?,
        epsilon: args.f64("epsilon").map_err(|e| anyhow::anyhow!("{e}"))?,
        ..Default::default()
    };

    // ε sweep (Fig. 1a analytic)
    let mut t = Table::new(&["epsilon", "b*", "theta*", "V", "H", "pred 𝒯 (s)"]);
    for eps in [0.005, 0.01, 0.02, 0.05, 0.1] {
        let plan = defl_opt::closed_form(&PlanInputs { epsilon: eps, ..base });
        t.row(&[
            format!("{eps}"),
            plan.batch.to_string(),
            format!("{:.3}", plan.theta),
            plan.local_rounds.to_string(),
            format!("{:.1}", plan.rounds),
            format!("{:.1}", plan.overall_time),
        ]);
    }
    println!("ε sweep (M={}, T_cm={}s):\n{}", base.m, base.t_cm, t.render());

    // device-count sweep — how the plan shifts with M
    let mut t = Table::new(&["M", "b*", "theta*", "V", "H", "pred 𝒯 (s)"]);
    for m in [2usize, 5, 10, 20, 50] {
        let plan = defl_opt::closed_form(&PlanInputs { m, ..base });
        t.row(&[
            m.to_string(),
            plan.batch.to_string(),
            format!("{:.3}", plan.theta),
            plan.local_rounds.to_string(),
            format!("{:.1}", plan.rounds),
            format!("{:.1}", plan.overall_time),
        ]);
    }
    println!("device sweep (ε={}):\n{}", base.epsilon, t.render());

    // θ sweep: H + compute share (Fig. 1d analytic)
    let mut t = Table::new(&["theta", "V", "H", "T_round (s)", "compute share"]);
    for theta in [0.05, 0.15, 0.3, 0.5, 0.9] {
        let alpha = (1.0f64 / theta).ln();
        let v = convergence::local_rounds(base.nu, theta);
        let h = convergence::rounds_to_epsilon(base.c, 32.0, base.epsilon, base.m, base.nu, alpha);
        let t_cp = 32.0 * base.t_cp_per_sample;
        let t_round = convergence::round_wall_time(base.t_cm, v, t_cp);
        let share = v as f64 * t_cp / t_round;
        t.row(&[
            format!("{theta}"),
            v.to_string(),
            format!("{h:.1}"),
            format!("{t_round:.3}"),
            format!("{:.0}%", share * 100.0),
        ]);
    }
    println!("θ sweep at b=32 (Fig. 1d):\n{}", t.render());
    Ok(())
}
