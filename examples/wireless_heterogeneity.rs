//! Wireless-heterogeneity scenario: what the paper's Section II-C channel
//! model implies for DEFL's plan under different network conditions —
//! bandwidth, cell size, OFDMA contention, fading. Shows "to talk or to
//! work" shifting: as the channel degrades, eq. (29) pushes more work
//! (higher α, larger b) onto the devices.
//!
//! ```sh
//! cargo run --release --example wireless_heterogeneity
//! ```

use defl::compute::gpu::{FleetConfig, GpuFleet};
use defl::defl_opt::{self, PlanInputs};
use defl::metrics::Table;
use defl::wireless::channel::{BandwidthPolicy, ChannelConfig};
use defl::wireless::Channel;

fn plan_for(cfg: ChannelConfig, label: &str, table: &mut Table) {
    const UPDATE_BITS: f64 = 103_018.0 * 32.0; // mnist_cnn update size
    const BITS_PER_SAMPLE: f64 = 28.0 * 28.0 * 32.0;
    let channel = Channel::new(cfg, 10, 42);
    let fleet = GpuFleet::new(&FleetConfig::default(), 42);
    let t_cm = channel.expected_round_time(UPDATE_BITS);
    let t_cps = fleet.bottleneck_seconds_per_sample(BITS_PER_SAMPLE);
    let plan = defl_opt::closed_form(&PlanInputs {
        t_cm,
        t_cp_per_sample: t_cps,
        ..Default::default()
    });
    table.row(&[
        label.to_string(),
        format!("{t_cm:.3}"),
        plan.batch.to_string(),
        format!("{:.3}", plan.theta),
        plan.local_rounds.to_string(),
        format!("{:.1}", plan.rounds),
        format!("{:.1}", plan.overall_time),
    ]);
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&[
        "scenario", "T_cm (s)", "b*", "theta*", "V", "H", "pred 𝒯 (s)",
    ]);

    plan_for(ChannelConfig::default(), "paper default (20 MHz)", &mut table);

    let mut c = ChannelConfig::default();
    c.bandwidth_hz = 5e6;
    plan_for(c, "narrow band (5 MHz)", &mut table);

    let mut c = ChannelConfig::default();
    c.policy = BandwidthPolicy::Ofdma;
    plan_for(c, "OFDMA contention (B/M)", &mut table);

    let mut c = ChannelConfig::default();
    c.max_radius_m = 2000.0;
    plan_for(c, "large cell (2 km)", &mut table);

    let mut c = ChannelConfig::default();
    c.tx_power_dbm = 10.0;
    plan_for(c, "low tx power (10 dBm)", &mut table);

    println!("\nDEFL plan vs channel conditions (worse channel ⇒ work more, talk less):");
    println!("{}", table.render());

    // Straggler study: compute heterogeneity inflates T_cp (eq. 5 max).
    let mut t = Table::new(&["fleet", "t_cp/sample (s)", "b*", "V", "pred 𝒯 (s)"]);
    let scenarios =
        [("homogeneous (paper)", 0.0), ("mild jitter", 0.2), ("severe stragglers", 0.5)];
    for (label, het) in scenarios {
        let mut fc = FleetConfig::default();
        fc.heterogeneity = het;
        fc.max_freq_hz = 4e9; // let jitter act (paper cap binds otherwise)
        let fleet = GpuFleet::new(&fc, 7);
        let t_cps = fleet.bottleneck_seconds_per_sample(28.0 * 28.0 * 32.0);
        let channel = Channel::new(ChannelConfig::default(), 10, 42);
        let t_cm = channel.expected_round_time(103_018.0 * 32.0);
        let plan = defl_opt::closed_form(&PlanInputs {
            t_cm,
            t_cp_per_sample: t_cps,
            ..Default::default()
        });
        t.row(&[
            label.to_string(),
            format!("{t_cps:.2e}"),
            plan.batch.to_string(),
            plan.local_rounds.to_string(),
            format!("{:.1}", plan.overall_time),
        ]);
    }
    println!("straggler study (slower bottleneck ⇒ smaller b*, fewer local rounds):");
    println!("{}", t.render());
    Ok(())
}
