//! Fleet-scale open-world churn smoke (DESIGN.md §11) — the CI scenario.
//!
//! A 1000-device fleet lives through everything the `[churn]` model can
//! throw at it, under all three round engines on the native backend:
//! 60% of the fleet is up at 𝒯 = 0, a flash crowd brings everyone else
//! at churn step 2, Poisson drops kill devices *mid-round* (their uplinks
//! are lost through the engines' outage paths), and dropped devices
//! rejoin — recovering their seed-derived shards, because the `Device`
//! objects persist. The run must still converge: final train loss below
//! first, under every engine, or the process exits non-zero.
//!
//! ```sh
//! cargo run --release --example churn_fleet -- \
//!     [--devices 1000] [--rounds 6] [--threads 4] [--out churn_fleet_metrics.json]
//! ```
//!
//! Writes the three engines' full metrics logs (phase / fleet_size /
//! joins / drops columns included) to `--out` — the artifact CI uploads.

use defl::config::{DatasetKind, ExperimentConfig, Policy};
use defl::coordinator::{ChurnEventKind, ChurnKind, EngineKind, FlSystem};
use defl::metrics::Table;
use defl::util::cli::Cli;
use defl::util::json::Json;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("churn_fleet", "1000-device open-world churn smoke, all three engines")
        .opt("devices", "1000", "fleet size M")
        .opt("rounds", "6", "rounds per engine")
        .opt("threads", "4", "thread-pool size for the training fan-out")
        .opt("seed", "7", "base seed")
        .opt("out", "churn_fleet_metrics.json", "metrics JSON path (CI artifact)");
    let args = cli
        .parse(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let devices = args.usize("devices").map_err(|e| anyhow::anyhow!("{e}"))?;
    let rounds = args.usize("rounds").map_err(|e| anyhow::anyhow!("{e}"))?;
    let threads = args.usize("threads").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed = args.u64("seed").map_err(|e| anyhow::anyhow!("{e}"))?;
    let out = args.str("out");

    let mut table = Table::new(&[
        "engine", "loss first→last", "fleet min→max", "joins", "mid-round deaths", "waited 𝒯 (s)",
    ]);
    let mut logs: Vec<(&'static str, Json)> = Vec::new();
    for kind in [EngineKind::Sync, EngineKind::Deadline, EngineKind::AsyncBuffered] {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("churn-fleet-{}", kind.label());
        cfg.dataset = DatasetKind::Tiny;
        cfg.devices = devices;
        cfg.train_per_device = 8;
        cfg.test_size = 256;
        cfg.threads = threads;
        cfg.seed = seed;
        cfg.policy = Policy::Fixed { batch: 8, local_rounds: 2 };
        cfg.lr = 0.05;
        cfg.backend = defl::runtime::BackendKind::Native;
        cfg.engine.kind = kind;
        cfg.max_rounds = rounds;
        cfg.eval_every = rounds;
        // the open world: 60% up at 𝒯=0, a flash crowd at churn step 2,
        // steady Poisson departures (mid-round deaths) and rejoins
        cfg.churn.kind = ChurnKind::FlashCrowd;
        cfg.churn.initial_active = 0.6;
        cfg.churn.min_clients = (devices / 5).max(1);
        cfg.churn.flash_step = 2;
        cfg.churn.flash_size = 0; // the flash brings everyone still out
        cfg.churn.join_rate = 0.3;
        cfg.churn.drop_rate = 0.15;

        let mut sys = FlSystem::build(cfg)?;
        let born: Vec<Vec<usize>> = sys.devices.iter().map(|d| d.shard.clone()).collect();
        sys.run()?;

        let first = sys.log.rounds.first().expect("ran").train_loss;
        let last = sys.log.rounds.last().expect("ran").train_loss;
        anyhow::ensure!(
            last < first,
            "{}: churned fleet failed to converge: {first:.4} -> {last:.4}",
            kind.label()
        );
        let fleet_min = sys.log.rounds.iter().map(|r| r.fleet_size).min().expect("ran");
        let fleet_max = sys.log.rounds.iter().map(|r| r.fleet_size).max().expect("ran");
        let joins: usize = sys.log.rounds.iter().map(|r| r.joins).sum();
        let deaths: usize = sys.log.rounds.iter().map(|r| r.drops).sum();
        anyhow::ensure!(
            fleet_max == devices,
            "{}: the flash crowd must fill the fleet",
            kind.label()
        );
        anyhow::ensure!(deaths > 0, "{}: this schedule kills someone mid-round", kind.label());
        // rejoin-recovers-shard: someone went Drop → Join, and every
        // device still holds the exact shard it was born with
        let mut dropped_once = vec![false; devices];
        let mut rejoined = false;
        for e in sys.membership.events() {
            match e.kind {
                ChurnEventKind::Drop => dropped_once[e.device] = true,
                ChurnEventKind::Join if dropped_once[e.device] => rejoined = true,
                ChurnEventKind::Join => {}
            }
        }
        anyhow::ensure!(rejoined, "{}: no device rejoined", kind.label());
        for (d, b) in sys.devices.iter().zip(&born) {
            anyhow::ensure!(&d.shard == b, "device {} lost its shard", d.id);
        }

        table.row(&[
            kind.label().into(),
            format!("{first:.4}→{last:.4}"),
            format!("{fleet_min}→{fleet_max}"),
            format!("{joins}"),
            format!("{deaths}"),
            format!("{:.2}", sys.clock.waited()),
        ]);
        logs.push((kind.label(), sys.log.to_json()));
    }

    println!("open-world churn, M={devices}, {rounds} rounds/engine:");
    println!("{}", table.render());
    Json::obj(logs).write_file(&out)?;
    println!("wrote {out}");
    Ok(())
}
