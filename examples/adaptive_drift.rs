//! Static vs adaptive DEFL planning on a drifting fleet (DESIGN.md §10).
//!
//! Runs the same seeded scenario twice — once with the round-0 plan
//! frozen (`controller.replan_every = 0`) and once re-planning every
//! round — on a channel that deterministically improves as the devices
//! drift toward the cell (`drift.trend_db_per_round < 0`), then prints
//! the per-mode plan trajectory and the overall-time delta.
//!
//! ```sh
//! cargo run --release --example adaptive_drift -- \
//!     [--devices 4] [--rounds 30] [--trend -1.5] [--replan-every 1]
//! ```
//!
//! Flip the trend positive to watch the honest trade in the other
//! direction: a degrading channel makes the adaptive run *work more* per
//! round (larger b*, V), which costs virtual time at a fixed round count
//! while buying more progress per round (EXPERIMENTS.md §controller).

use defl::config::{DatasetKind, ExperimentConfig, Policy};
use defl::coordinator::FlSystem;
use defl::experiments::reduction_pct;
use defl::metrics::Table;
use defl::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("adaptive_drift", "static vs adaptive DEFL planning under channel drift")
        .opt("devices", "4", "fleet size M")
        .opt("rounds", "30", "rounds to run both modes for")
        .opt("trend", "-1.5", "drift.trend_db_per_round (negative improves the channel)")
        .opt("replan-every", "1", "adaptive re-plan cadence in rounds")
        .opt("seed", "7", "base seed");
    let args = cli
        .parse(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let devices = args.usize("devices").map_err(|e| anyhow::anyhow!("{e}"))?;
    let rounds = args.usize("rounds").map_err(|e| anyhow::anyhow!("{e}"))?;
    let trend = args.f64("trend").map_err(|e| anyhow::anyhow!("{e}"))?;
    let cadence = args.usize("replan-every").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed = args.u64("seed").map_err(|e| anyhow::anyhow!("{e}"))?;

    let build = |replan_every: usize| -> anyhow::Result<FlSystem> {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("adaptive-drift-replan{replan_every}");
        cfg.dataset = DatasetKind::Tiny;
        cfg.devices = devices;
        cfg.train_per_device = 96;
        cfg.test_size = 256;
        cfg.seed = seed;
        cfg.policy = Policy::Defl;
        cfg.backend = defl::runtime::BackendKind::Native;
        cfg.max_rounds = rounds;
        cfg.eval_every = rounds;
        cfg.wireless.tx_power_dbm = 0.0; // low SNR: talk is dear at round 0
        cfg.wireless.fast_fading = false;
        cfg.wireless.drift.trend_db_per_round = trend;
        cfg.wireless.drift.clamp_db = 60.0;
        cfg.fleet.parallel_width = 1; // literal eq. (4): planner == priced delay
        cfg.controller.replan_every = replan_every;
        cfg.controller.ewma = 1.0; // fading-free: track the last round exactly
        cfg.controller.deadband = 0.0;
        FlSystem::build(cfg)
    };

    let mut table = Table::new(&[
        "mode", "b first→last", "V first→last", "total 𝒯 (s)", "final loss", "est T_cm last (s)",
    ]);
    let mut totals = Vec::new();
    // an explicit --replan-every 0 is honoured: both rows run static and
    // the printed delta degenerates to 0 (a useful sanity check)
    for (mode, replan_every) in [("static", 0usize), ("adaptive", cadence)] {
        let mut sys = build(replan_every)?;
        sys.run()?;
        let first = sys.log.rounds.first().expect("ran at least one round").clone();
        let last = sys.log.rounds.last().expect("ran at least one round").clone();
        totals.push(sys.log.overall_time());
        table.row(&[
            mode.into(),
            format!("{}→{}", first.plan_b, last.plan_b),
            format!("{}→{}", first.local_rounds, last.local_rounds),
            format!("{:.3}", sys.log.overall_time()),
            format!("{:.4}", last.train_loss),
            if last.est_t_cm.is_finite() { format!("{:.5}", last.est_t_cm) } else { "-".into() },
        ]);
    }
    println!(
        "static vs adaptive planning (trend {trend:+.1} dB/round over {rounds} rounds, \
         M={devices}):"
    );
    println!("{}", table.render());
    let delta = reduction_pct(totals[1], totals[0]);
    println!(
        "adaptive vs static overall time: {:.3}s vs {:.3}s ({delta:+.1}% saved)",
        totals[1], totals[0]
    );
    Ok(())
}
