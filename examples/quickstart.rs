//! Quickstart: the end-to-end driver proving all three layers compose.
//!
//! Trains the small MLP over 4 simulated edge devices for a few rounds of
//! real federated SGD, prints the loss curve and the DEFL plan, and
//! reports both virtual (modeled) and wall time. Any config key can be
//! overridden on the command line (`[--set] section.key=value`) — most
//! usefully the training substrate:
//!
//! ```sh
//! # PJRT (the default when compiled in; executes the JAX/Pallas artifact)
//! make artifacts && cargo run --release --example quickstart
//! # pure-Rust native backend — no artifacts, no XLA
//! cargo run --release --example quickstart -- --set backend.kind=native
//! ```

use defl::config::{DatasetKind, ExperimentConfig, Policy};
use defl::coordinator::FlSystem;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.dataset = DatasetKind::Tiny; // 8×8 synthetic, the `mlp` model
    cfg.devices = 4;
    cfg.train_per_device = 128;
    cfg.test_size = 512;
    cfg.max_rounds = 12;
    cfg.eval_every = 3;
    cfg.policy = Policy::Defl;
    cfg.out = Some("results/quickstart.json".into());
    // `--set section.key=value` overrides (the `--set` token is optional).
    for arg in std::env::args().skip(1) {
        if arg == "--set" {
            continue;
        }
        if arg.contains('=') {
            cfg.set_override(&arg)?;
        } else {
            anyhow::bail!("unrecognised argument {arg:?} (expected section.key=value)");
        }
    }

    println!("== DEFL quickstart ({} backend) ==", cfg.backend.label());
    let mut sys = FlSystem::build(cfg)?;
    if let Some(plan) = &sys.resolved.plan {
        println!(
            "DEFL plan: b*={} θ*={:.3} V={} → predicted H={:.0} rounds, 𝒯={:.1}s",
            plan.batch, plan.theta, plan.local_rounds, plan.rounds, plan.overall_time
        );
    }
    let outcome = sys.run()?;

    println!("\nround  virt-time  train-loss  test-acc");
    for r in &sys.log.rounds {
        println!(
            "{:5}  {:9.2}  {:10.4}  {}",
            r.round,
            r.virtual_time,
            r.train_loss,
            if r.test_accuracy.is_finite() {
                format!("{:.4}", r.test_accuracy)
            } else {
                "-".into()
            }
        );
    }
    println!(
        "\nfinished: {} rounds, overall 𝒯 = {:.1}s (virtual), {:.1}s wall, accuracy {:.3}",
        outcome.rounds, outcome.overall_time, outcome.wall_seconds, outcome.final_test_accuracy
    );
    println!("run log: results/quickstart.json");
    Ok(())
}
