//! Quickstart: the end-to-end driver proving all three layers compose.
//!
//! Trains the small MLP over 4 simulated edge devices for a few rounds of
//! real federated SGD (PJRT executes the JAX/Pallas artifact), prints the
//! loss curve and the DEFL plan, and reports both virtual (modeled) and
//! wall time.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use defl::config::{DatasetKind, ExperimentConfig, Policy};
use defl::coordinator::FlSystem;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.dataset = DatasetKind::Tiny; // 8×8 synthetic, mlp artifact
    cfg.devices = 4;
    cfg.train_per_device = 128;
    cfg.test_size = 512;
    cfg.max_rounds = 12;
    cfg.eval_every = 3;
    cfg.policy = Policy::Defl;
    cfg.out = Some("results/quickstart.json".into());

    println!("== DEFL quickstart ==");
    let mut sys = FlSystem::build(cfg)?;
    if let Some(plan) = &sys.resolved.plan {
        println!(
            "DEFL plan: b*={} θ*={:.3} V={} → predicted H={:.0} rounds, 𝒯={:.1}s",
            plan.batch, plan.theta, plan.local_rounds, plan.rounds, plan.overall_time
        );
    }
    let outcome = sys.run()?;

    println!("\nround  virt-time  train-loss  test-acc");
    for r in &sys.log.rounds {
        println!(
            "{:5}  {:9.2}  {:10.4}  {}",
            r.round,
            r.virtual_time,
            r.train_loss,
            if r.test_accuracy.is_finite() {
                format!("{:.4}", r.test_accuracy)
            } else {
                "-".into()
            }
        );
    }
    println!(
        "\nfinished: {} rounds, overall 𝒯 = {:.1}s (virtual), {:.1}s wall, accuracy {:.3}",
        outcome.rounds, outcome.overall_time, outcome.wall_seconds, outcome.final_test_accuracy
    );
    println!("run log: results/quickstart.json");
    Ok(())
}
