//! Straggler scenario × round engines: the "to talk or to work" trade-off
//! when the fleet is heterogeneous and the schedule is a choice.
//!
//! Builds one straggling fleet (DVFS jitter, frequency cap lifted so it
//! shows) and runs the same fixed-seed FL job under all three round
//! engines:
//!
//! * `sync`           — the paper's Algorithm 1: every round waits for the
//!                      slowest device;
//! * `deadline`       — the server closes each round at `T_dl`; stragglers
//!                      are dropped and FedAvg reweights over survivors;
//! * `async_buffered` — FedBuff-style: aggregate the K earliest arrivals,
//!                      staleness-discounted, clock advances per-arrival.
//!
//! ```sh
//! make artifacts && cargo run --release --example straggler_engines
//! ```

use defl::config::{DatasetKind, ExperimentConfig, Policy};
use defl::coordinator::{EngineKind, FlSystem};
use defl::metrics::Table;

fn scenario(kind: EngineKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("straggler-{}", kind.label());
    cfg.dataset = DatasetKind::Tiny;
    cfg.devices = 8;
    cfg.train_per_device = 96;
    cfg.test_size = 512;
    cfg.policy = Policy::Fixed { batch: 16, local_rounds: 4 };
    cfg.max_rounds = 12;
    cfg.eval_every = 4;
    // the straggler fleet: ±40% DVFS jitter, cap lifted so it bites
    cfg.fleet.heterogeneity = 0.4;
    cfg.fleet.max_freq_hz = 4e9;
    cfg.engine.kind = kind;
    cfg
}

fn main() -> anyhow::Result<()> {
    println!("== round engines under a straggling fleet ==\n");
    let mut table = Table::new(&[
        "engine", "rounds", "total 𝒯 (s)", "final loss", "best acc", "mean part.", "dropped",
        "staleness",
    ]);
    let mut sync_time = f64::NAN;
    for kind in [EngineKind::Sync, EngineKind::Deadline, EngineKind::AsyncBuffered] {
        let mut sys = FlSystem::build(scenario(kind))?;
        let outcome = sys.run()?;
        if kind == EngineKind::Sync {
            sync_time = outcome.overall_time;
        }
        let speedup = sync_time / outcome.overall_time;
        println!(
            "{:>14}: 𝒯={:8.2}s  ({speedup:.2}× vs sync)  acc={:.4}",
            kind.label(),
            outcome.overall_time,
            outcome.final_test_accuracy
        );
        table.row(&[
            kind.label().into(),
            outcome.rounds.to_string(),
            format!("{:.2}", outcome.overall_time),
            format!("{:.4}", outcome.final_train_loss),
            format!("{:.4}", sys.log.best_accuracy()),
            format!("{:.2}", sys.log.mean_participation()),
            sys.log.total_dropped().to_string(),
            format!("{:.2}", sys.log.mean_staleness()),
        ]);
    }
    println!("\n{}", table.render());
    println!(
        "deadline drops the tail (participation < M); async_buffered never waits for it\n\
         (staleness > 0). Same seed, same fleet, same channel — only the schedule differs."
    );
    Ok(())
}
