//! MNIST scenario: the paper's headline workload (Fig. 2 left).
//!
//! Runs DEFL and the FedAvg baseline on the MNIST-like task with the
//! paper's setting (M=10 devices, lr=0.01, B=20 MHz, f_m=2 GHz) and
//! prints the time-to-accuracy comparison.
//!
//! ```sh
//! cargo run --release --example mnist_defl            # full
//! DEFL_FAST=1 cargo run --release --example mnist_defl # smoke
//! ```

use defl::config::{presets, Policy};
use defl::coordinator::FlSystem;
use defl::experiments::reduction_pct;
use defl::metrics::Table;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DEFL_FAST").as_deref() == Ok("1");
    let mut results = Vec::new();
    for (label, policy) in [
        ("DEFL", Policy::Defl),
        ("FedAvg", presets::fedavg()),
    ] {
        let mut cfg = presets::fig2_mnist(policy);
        cfg.name = format!("example-mnist-{label}");
        cfg.out = Some(format!("results/example_mnist_{label}.json"));
        if fast {
            cfg.max_rounds = 3;
            cfg.train_per_device = 64;
            cfg.test_size = 256;
            cfg.eval_every = 3;
        }
        let mut sys = FlSystem::build(cfg)?;
        let outcome = sys.run()?;
        results.push((label, outcome, sys.log.clone()));
    }

    let defl_time = results[0].1.overall_time;
    let mut table = Table::new(&["method", "rounds", "overall 𝒯 (s)", "accuracy", "reduction"]);
    for (label, outcome, _) in &results {
        table.row(&[
            label.to_string(),
            outcome.rounds.to_string(),
            format!("{:.1}", outcome.overall_time),
            format!("{:.4}", outcome.final_test_accuracy),
            if *label == "DEFL" {
                "-".into()
            } else {
                format!("{:.0}%", reduction_pct(defl_time, outcome.overall_time))
            },
        ]);
    }
    println!("\nMNIST (paper Fig. 2 left; paper reports ≈70% reduction vs FedAvg):");
    println!("{}", table.render());
    Ok(())
}
