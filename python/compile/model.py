"""L2 — the paper's learning workload in JAX, calling the L1 Pallas kernels.

The paper evaluates DEFL with a small CNN on MNIST and CIFAR-10 (Section
VI-A: 1 server, 10 devices, lr = 0.01, mini-batch SGD). This module defines:

* ``mnist_cnn`` / ``cifar_cnn`` — conv→relu→pool ×2, then two dense layers.
  The dense layers are the Pallas fused-linear kernel
  (:mod:`compile.kernels.fused_linear`), wired with a custom VJP so the
  backward pass lands on Pallas matmuls too.
* ``mlp`` — a tiny model for the quickstart example and fast tests.
* ``train_step`` — one mini-batch SGD iteration: fwd, bwd, and the Pallas
  fused update (:mod:`compile.kernels.sgd`). This is the computation DEFL's
  eq. (4) prices at ``G_m·b / f_m``; the rust coordinator executes its
  AOT-lowered HLO ``V`` times per round per device.
* ``eval_step`` — summed loss + correct-prediction count over a batch.

Everything here runs at build time only (``make artifacts``); the lowered
HLO text is the interchange with the rust runtime.

Parameters are a flat ``dict[str, Array]`` with a deterministic leaf order
(``PARAM_ORDER`` per model) — the same order the manifest records and the
rust side uses for execute() argument marshalling.
"""

import os

import jax
import jax.numpy as jnp

from compile.kernels import conv as conv_kernel
from compile.kernels import fused_linear, ref, sgd

# Escape hatch: DEFL_USE_PALLAS=0 swaps the Pallas kernels for the pure-jnp
# references (used by tests to isolate kernel bugs from model bugs).
USE_PALLAS = os.environ.get("DEFL_USE_PALLAS", "1") != "0"
# DEFL_PALLAS_CONV=1 routes convolutions through the Pallas nine-GEMM
# mapping (compile.kernels.conv). Default off for the shipped artifacts:
# interpret-mode dispatch cost on CPU-PJRT; see conv.py docstring.
PALLAS_CONV = os.environ.get("DEFL_PALLAS_CONV", "0") == "1"


def _dense(x, w, b, activation):
    if USE_PALLAS:
        return fused_linear.linear_vjp(x, w, b, activation)
    return ref.linear(x, w, b, activation)


def _sgd_tree(params, grads, lr):
    if USE_PALLAS:
        return sgd.sgd_update_tree(params, grads, lr)
    return jax.tree_util.tree_map(lambda w, g: ref.sgd_update(w, g, lr),
                                  params, grads)


# --------------------------------------------------------------------------
# Model zoo
# --------------------------------------------------------------------------

MODELS = {
    # name: (height, width, channels, classes)
    "mnist_cnn": dict(height=28, width=28, channels=1, classes=10,
                      conv1=8, conv2=16, hidden=128),
    "cifar_cnn": dict(height=32, width=32, channels=3, classes=10,
                      conv1=16, conv2=32, hidden=128),
    "mlp": dict(height=8, width=8, channels=1, classes=10, hidden=32),
}


def param_specs(name):
    """Ordered ``[(leaf_name, shape)]`` for a model — the manifest contract."""
    cfg = MODELS[name]
    h, w, c, k = cfg["height"], cfg["width"], cfg["channels"], cfg["classes"]
    if name == "mlp":
        d = h * w * c
        hid = cfg["hidden"]
        return [
            ("fc1_w", (d, hid)), ("fc1_b", (hid,)),
            ("fc2_w", (hid, k)), ("fc2_b", (k,)),
        ]
    c1, c2, hid = cfg["conv1"], cfg["conv2"], cfg["hidden"]
    # Two 3x3 SAME convs, each followed by 2x2 maxpool.
    fh, fw = h // 4, w // 4
    flat = fh * fw * c2
    return [
        ("conv1_w", (3, 3, c, c1)), ("conv1_b", (c1,)),
        ("conv2_w", (3, 3, c1, c2)), ("conv2_b", (c2,)),
        ("fc1_w", (flat, hid)), ("fc1_b", (hid,)),
        ("fc2_w", (hid, k)), ("fc2_b", (k,)),
    ]


def param_order(name):
    return [n for n, _ in param_specs(name)]


def param_count(name):
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(name))


def init_params(name, seed=0):
    """He-initialised parameters as an ordered dict of f32 leaves."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for leaf, shape in param_specs(name):
        key, sub = jax.random.split(key)
        if leaf.endswith("_b"):
            params[leaf] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            scale = jnp.sqrt(2.0 / fan_in)
            params[leaf] = scale * jax.random.normal(sub, shape, jnp.float32)
    return params


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _conv_relu_pool(x, w, b):
    """3x3 SAME conv (NHWC) + bias + relu + 2x2 maxpool."""
    if USE_PALLAS and PALLAS_CONV:
        out = conv_kernel.conv3x3_same(x, w)
    else:
        out = jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
    out = jax.nn.relu(out + b[None, None, None, :])
    return jax.lax.reduce_window(
        out, -jnp.inf, jax.lax.max,
        window_dimensions=(1, 2, 2, 1), window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def forward(name, params, x):
    """Logits for a batch ``x`` of shape (b, h, w, c), values in [0, 1]."""
    if name == "mlp":
        bsz = x.shape[0]
        h = x.reshape((bsz, -1))
        h = _dense(h, params["fc1_w"], params["fc1_b"], "relu")
        return _dense(h, params["fc2_w"], params["fc2_b"], "none")
    h = _conv_relu_pool(x, params["conv1_w"], params["conv1_b"])
    h = _conv_relu_pool(h, params["conv2_w"], params["conv2_b"])
    bsz = h.shape[0]
    h = h.reshape((bsz, -1))
    h = _dense(h, params["fc1_w"], params["fc1_b"], "relu")
    return _dense(h, params["fc2_w"], params["fc2_b"], "none")


def loss_fn(name, params, x, y):
    """Mean softmax cross-entropy over the batch; y is int32 labels."""
    logits = forward(name, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# AOT entry points (lowered by aot.py)
# --------------------------------------------------------------------------

def train_step(name):
    """Returns fn(params_leaves..., x, y, lr) → (new_leaves..., loss).

    A flat positional signature (leaf order = ``param_order(name)``) keeps
    the HLO parameter list explicit for the rust runtime.
    """
    order = param_order(name)

    def step(*args):
        leaves = args[: len(order)]
        x, y, lr = args[len(order):]
        params = dict(zip(order, leaves))
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(name, p, x, y))(params)
        new = _sgd_tree(params, grads, lr)
        return tuple(new[k] for k in order) + (loss,)

    return step


def eval_step(name):
    """Returns fn(params_leaves..., x, y) → (summed_loss, correct_count)."""
    order = param_order(name)

    def step(*args):
        leaves = args[: len(order)]
        x, y = args[len(order):]
        params = dict(zip(order, leaves))
        logits = forward(name, params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        y32 = y.astype(jnp.int32)
        nll = -jnp.take_along_axis(logp, y32[:, None], axis=-1)[:, 0]
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y32).astype(jnp.float32))
        return jnp.sum(nll), correct

    return step


def example_batch(name, batch, seed=0):
    """Deterministic example inputs used for lowering and golden vectors."""
    cfg = MODELS[name]
    key = jax.random.PRNGKey(1000 + seed)
    kx, ky = jax.random.split(key)
    x = jax.random.uniform(
        kx, (batch, cfg["height"], cfg["width"], cfg["channels"]),
        jnp.float32)
    y = jax.random.randint(ky, (batch,), 0, cfg["classes"], jnp.int32)
    return x, y
