"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness gate).

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here. `python/tests/test_kernels.py` sweeps shapes and dtypes
with hypothesis and asserts `assert_allclose(kernel(...), ref(...))`.
The references are also what the L2 model uses when
``DEFL_USE_PALLAS=0`` (debug escape hatch).
"""

import jax.numpy as jnp


def linear(x, w, b, activation="none"):
    """Dense layer reference: ``act(x @ w + b)``.

    Args:
      x: ``(m, k)`` activations.
      w: ``(k, n)`` weights.
      b: ``(n,)`` bias.
      activation: ``"none"`` or ``"relu"``.

    Returns:
      ``(m, n)`` output in the accumulation dtype (f32).
    """
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    out = out + b.astype(jnp.float32)[None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def sgd_update(w, g, lr):
    """SGD parameter update reference: ``w - lr * g`` (elementwise)."""
    return w - lr * g


def matmul(x, w):
    """Plain matmul reference (no bias / activation)."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def conv3x3_same(x, w):
    """3×3 SAME NHWC conv reference via lax.conv_general_dilated."""
    import jax

    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
