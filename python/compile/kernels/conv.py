"""Pallas 3×3 SAME convolution as nine shifted GEMMs — the TPU mapping.

Hardware adaptation (DESIGN.md §7): a GPU implements conv with im2col +
warp-level tiles or implicit-GEMM threadblocks. On a TPU the idiomatic
mapping feeds the MXU systolic array directly: a K_h×K_w convolution is
Σ_{ky,kx} shift(x, ky, kx) @ W[ky, kx] — nine (NHW, C)×(C, K) GEMMs whose
FLOPs all land in the Pallas tiled-matmul kernel
(:mod:`compile.kernels.fused_linear`). Shifts/padding are pure data
movement and stay in XLA.

The backward pass uses the same trick:
  dW[ky,kx] = shift(x, ky, kx)ᵀ @ dy          (nine GEMMs)
  dx        = Σ_{ky,kx} shift⁻¹(dy @ W[ky,kx]ᵀ)  (nine GEMMs)

``conv3x3_same`` carries a ``jax.custom_vjp`` so ``jax.grad`` of the L2
model lands on Pallas GEMMs end to end.

Enabled in the L2 model with ``DEFL_PALLAS_CONV=1`` at AOT time. The
shipped artifacts default to XLA's native conv purely for CPU-interpret
wall-clock (the nine interpret-mode pallas_call dispatches per conv per
step are slow on the CPU testbed); both paths are gated by the same
oracle (:func:`ref` / pytest) so they are interchangeable.
"""

import jax
import jax.numpy as jnp

from compile.kernels import fused_linear


def _shift_slices(h, w, ky, kx):
    """Slice bounds implementing SAME padding for offset (ky−1, kx−1)."""
    # output (y, x) reads input (y + ky - 1, x + kx - 1)
    dy0 = max(0, ky - 1)
    dy1 = min(h, h + ky - 1)
    sy0 = max(0, 1 - ky)
    dx0 = max(0, kx - 1)
    dx1 = min(w, w + kx - 1)
    sx0 = max(0, 1 - kx)
    return dy0, dy1, sy0, dx0, dx1, sx0


def _shifted(x, ky, kx):
    """``shift(x, ky, kx)`` with zero fill: out[y,x] = x[y+ky−1, x+kx−1]."""
    n, h, w, c = x.shape
    dy0, dy1, sy0, dx0, dx1, sx0 = _shift_slices(h, w, ky, kx)
    out = jnp.zeros_like(x)
    span_y = dy1 - dy0
    span_x = dx1 - dx0
    return out.at[:, sy0:sy0 + span_y, sx0:sx0 + span_x, :].set(
        x[:, dy0:dy1, dx0:dx1, :]
    )


def _unshifted(x, ky, kx):
    """Inverse shift (used by dx): out[y+ky−1, x+kx−1] += x[y,x]."""
    return _shifted(x, 2 - ky, 2 - kx)


def _fwd_impl(x, w):
    n, h, wd, c = x.shape
    kh, kw, c2, k = w.shape
    assert (kh, kw) == (3, 3) and c2 == c, f"want 3x3 conv, got {w.shape}"
    acc = jnp.zeros((n * h * wd, k), jnp.float32)
    for ky in range(3):
        for kx in range(3):
            xs = _shifted(x, ky, kx).reshape(n * h * wd, c)
            acc = acc + fused_linear.matmul(xs, w[ky, kx])
    return acc.reshape(n, h, wd, k)


@jax.custom_vjp
def conv3x3_same(x, w):
    """3×3 SAME NHWC convolution; all FLOPs in Pallas GEMMs."""
    return _fwd_impl(x, w)


def _conv_fwd(x, w):
    return _fwd_impl(x, w), (x, w)


def _conv_bwd(res, dy):
    x, w = res
    n, h, wd, c = x.shape
    k = w.shape[-1]
    dyf = dy.reshape(n * h * wd, k).astype(jnp.float32)
    # dW: nine (C, K) blocks
    dw_blocks = []
    for ky in range(3):
        row = []
        for kx in range(3):
            xs = _shifted(x, ky, kx).reshape(n * h * wd, c)
            row.append(fused_linear.matmul(xs.T, dyf))
        dw_blocks.append(jnp.stack(row, axis=0))
    dw = jnp.stack(dw_blocks, axis=0)
    # dx: scatter each dy @ Wᵀ back through the inverse shift
    dx = jnp.zeros_like(x, dtype=jnp.float32)
    for ky in range(3):
        for kx in range(3):
            g = fused_linear.matmul(dyf, w[ky, kx].T).reshape(n, h, wd, c)
            dx = dx + _unshifted(g, ky, kx)
    return dx, dw


conv3x3_same.defvjp(_conv_fwd, _conv_bwd)
