"""Pallas fused dense layer: ``act(x @ w + b)`` — the L1 compute hot spot.

Hardware adaptation (paper GPU → TPU-style Pallas)
--------------------------------------------------
The paper's computation model (eq. 3) is GPU-centric: core/memory frequency,
warps, HBM. Rather than port CUDA threadblocks mechanically, the dense hot
spot is expressed the way a TPU wants it:

* The grid tiles the output ``(m, n)`` plane; each grid step owns one
  ``(bm, bn)`` output tile — the analogue of a threadblock, but scheduled
  by the Pallas grid over the MXU instead of SM warps.
* The contraction dimension ``k`` is the innermost grid axis; the output
  tile acts as an f32 accumulator that stays resident in VMEM across the
  ``k`` steps (its index map is k-invariant), so partial products never
  round-trip to HBM — the TPU analogue of shared-memory staging.
* Tile ``(bm, bk) @ (bk, bn)`` matches the 128×128 systolic array shape;
  accumulation is f32 via ``preferred_element_type``.

VMEM budget per grid step = ``bm*bk + bk*bn + bm*bn`` f32 words; with the
default 128/128/128 tiles that is 192 KiB — far under the ~16 MiB VMEM of a
TPU core, leaving headroom for double buffering (see DESIGN.md §9).

The kernel runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is gated against :mod:`ref` by pytest, and the
same HLO is what ``make artifacts`` ships to the rust runtime.

The backward pass is wired through ``jax.custom_vjp`` so that the L2 model's
``jax.grad`` also lands on Pallas matmuls (dx = dy @ wᵀ, dw = xᵀ @ dy).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tiles. Shapes that do not divide evenly fall back to
# the largest divisor tile (interpret mode has no padding cost; on a real
# TPU the divisor guard keeps every DMA aligned).
#
# Perf pass (EXPERIMENTS.md §Perf): bk=256 measured ~7% faster end-to-end
# train_step than bk=128 (fewer k-axis grid steps ⇒ less per-step dispatch)
# while keeping the largest tile residency at 176 KiB — ~1% of a TPU
# core's VMEM, leaving ample double-buffering headroom. bk∈{512,1024} and
# bm=bn=256 measured within noise (<5%), so tuning stopped per the
# three-flat-changes rule.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256


def _largest_divisor_tile(dim: int, preferred: int) -> int:
    """Largest tile ≤ preferred that divides dim (always ≥ 1)."""
    t = min(dim, preferred)
    while dim % t != 0:
        t -= 1
    return t


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile; innermost grid axis walks the k blocks.

    ``o_ref``'s index map ignores the k axis, so the tile stays in VMEM and
    doubles as the f32 accumulator.
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    del nk


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """Matmul tile with fused bias + activation epilogue on the last k step."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


def _tiles(m, n, k, bm, bn, bk):
    bm = _largest_divisor_tile(m, bm)
    bn = _largest_divisor_tile(n, bn)
    bk = _largest_divisor_tile(k, bk)
    return bm, bn, bk


def vmem_bytes(m, n, k, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Estimated VMEM residency (bytes/grid step) for the chosen tiling.

    Used by DESIGN.md §9 / EXPERIMENTS.md §Perf to justify tile choices
    against the ~16 MiB per-core budget.
    """
    bm, bn, bk = _tiles(m, n, k, bm, bn, bk)
    return 4 * (bm * bk + bk * bn + bm * bn)


def matmul(x, w, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Tiled Pallas matmul ``x @ w`` (f32 accumulation), interpret mode."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn, bk = _tiles(m, n, k, bm, bn, bk)
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def linear(x, w, b, activation="none", *, bm=DEFAULT_BM, bn=DEFAULT_BN,
           bk=DEFAULT_BK):
    """Fused dense layer ``act(x @ w + b)`` as a single Pallas kernel.

    Args / returns match :func:`ref.linear`.
    """
    if activation not in ("none", "relu"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm, bn, bk = _tiles(m, n, k, bm, bn, bk)
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_linear_kernel, nk=nk, activation=activation),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


# --- custom_vjp wiring so jax.grad stays on Pallas matmuls -----------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear_vjp(x, w, b, activation="none"):
    """Differentiable fused dense layer; bwd uses Pallas matmuls too."""
    return linear(x, w, b, activation)


def _linear_fwd(x, w, b, activation):
    out = linear(x, w, b, activation)
    return out, (x, w, out)


def _linear_bwd(activation, res, dy):
    x, w, out = res
    if activation == "relu":
        dy = dy * (out > 0).astype(dy.dtype)
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


linear_vjp.defvjp(_linear_fwd, _linear_bwd)
