"""Pallas fused SGD parameter update: ``w ← w − lr·g`` — the L1 update hot spot.

Each parameter leaf is updated by a single elementwise Pallas kernel. Leaves
are flattened to 1-D and tiled in VMEM-sized blocks (default 64 Ki elements,
i.e. 256 KiB f32 per operand per grid step — well inside VMEM), so the same
kernel serves every leaf shape. On TPU this is a pure VPU (vector unit)
kernel: one load of ``w``, one of ``g``, one FMA, one store — memory-bound
by construction, so the tiling is chosen for DMA alignment rather than
compute shape.

``lr`` enters as a scalar operand (not baked into the HLO) so the rust
coordinator can sweep learning rates without recompiling artifacts.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 1024  # f32 elements per grid step (256 KiB per ref)


def _largest_divisor_tile(dim: int, preferred: int) -> int:
    t = min(dim, preferred)
    while dim % t != 0:
        t -= 1
    return t


def _sgd_kernel(lr_ref, w_ref, g_ref, o_ref):
    o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(w, g, lr, *, block=DEFAULT_BLOCK):
    """Fused elementwise SGD step on one parameter leaf.

    Args:
      w: parameter leaf (any shape, f32).
      g: gradient of identical shape.
      lr: scalar learning rate (python float or 0-d/1-element array).

    Returns:
      Updated leaf with the same shape as ``w``.
    """
    assert w.shape == g.shape, f"shape mismatch {w.shape} vs {g.shape}"
    shape = w.shape
    n = w.size
    wf = w.reshape((n,))
    gf = g.reshape((n,))
    lr_arr = jnp.asarray(lr, jnp.float32).reshape((1,))
    bs = _largest_divisor_tile(n, block)

    out = pl.pallas_call(
        _sgd_kernel,
        grid=(n // bs,),
        in_specs=[
            # lr broadcast to every grid step.
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(lr_arr, wf, gf)
    return out.reshape(shape)


def sgd_update_tree(params, grads, lr, *, block=DEFAULT_BLOCK):
    """Apply :func:`sgd_update` across a pytree of parameter leaves."""
    return jax.tree_util.tree_map(
        lambda w, g: sgd_update(w, g, lr, block=block), params, grads
    )
