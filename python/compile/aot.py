"""AOT compiler: lower every (model × batch-size) entry point to HLO text.

This is the ONLY place Python touches the pipeline. ``make artifacts`` runs
it once; afterwards the rust coordinator is self-contained:

  artifacts/
    <model>_train_b<B>.hlo.txt   one mini-batch SGD step (fwd+bwd+update)
    <model>_eval_b<B>.hlo.txt    summed loss + correct count over a batch
    <model>_init.npz             seeded initial parameters (leaf order!)
    <model>_golden.npz           example batch + expected outputs for the
                                 rust integration tests (exact JAX numbers)
    manifest.json                the contract consumed by rust/src/runtime

Interchange format is **HLO text**, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import hashlib
import json
import os

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

# batch sizes needed by the experiments:
#  - fig1b sweeps b ∈ {16, 32, 64} on MNIST
#  - FedAvg baseline uses b=10 (paper Section VI), Rand uses b=16 / b=64
#  - DEFL's optimizer rounds b* to a power of two (8..64 covers the range)
TRAIN_BATCHES = {
    "mlp": [16, 32],
    "mnist_cnn": [8, 10, 16, 32, 64],
    "cifar_cnn": [16, 32, 64],
}
EVAL_BATCH = 256


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train(name, batch):
    cfg = M.MODELS[name]
    specs = [jax.ShapeDtypeStruct(s, jnp.float32)
             for _, s in M.param_specs(name)]
    x = jax.ShapeDtypeStruct(
        (batch, cfg["height"], cfg["width"], cfg["channels"]), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    return jax.jit(M.train_step(name)).lower(*specs, x, y, lr)


def lower_eval(name, batch):
    cfg = M.MODELS[name]
    specs = [jax.ShapeDtypeStruct(s, jnp.float32)
             for _, s in M.param_specs(name)]
    x = jax.ShapeDtypeStruct(
        (batch, cfg["height"], cfg["width"], cfg["channels"]), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return jax.jit(M.eval_step(name)).lower(*specs, x, y)


def write(path, text):
    with open(path, "w") as f:
        f.write(text)
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def golden_vectors(name, batch, params, lr=0.01):
    """Run one train step + one eval step in JAX; capture exact outputs."""
    order = M.param_order(name)
    x, y = M.example_batch(name, batch)
    leaves = [params[k] for k in order]
    out = jax.jit(M.train_step(name))(*leaves, x, y, jnp.float32(lr))
    new_leaves, loss = out[:-1], out[-1]
    # Eval golden uses the eval artifact's batch size so the rust
    # integration test can feed it straight into <model>_eval_b256.
    ex, ey = M.example_batch(name, EVAL_BATCH, seed=7)
    eval_out = jax.jit(M.eval_step(name))(*leaves, ex, ey)
    g = {"x": np.asarray(x), "y": np.asarray(y),
         "lr": np.asarray(lr, np.float32),
         "loss": np.asarray(loss),
         "eval_x": np.asarray(ex), "eval_y": np.asarray(ey),
         "eval_loss_sum": np.asarray(eval_out[0]),
         "eval_correct": np.asarray(eval_out[1])}
    for k, v in zip(order, new_leaves):
        g[f"new_{k}"] = np.asarray(v)
    return g


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    p.add_argument("--models", nargs="*", default=list(TRAIN_BATCHES))
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--skip-golden", action="store_true",
                   help="skip executing golden vectors (faster CI)")
    args = p.parse_args()
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)

    manifest = {"format": "hlo-text", "version": 1, "models": {}}
    for name in args.models:
        cfg = M.MODELS[name]
        specs = M.param_specs(name)
        entry = {
            "input": {k: cfg[k] for k in
                      ("height", "width", "channels", "classes")},
            "params": [{"name": n, "shape": list(s)} for n, s in specs],
            "param_count": int(sum(int(np.prod(s)) for _, s in specs)),
            "train": {}, "eval": {},
        }
        entry["update_bytes"] = 4 * entry["param_count"]

        params = M.init_params(name, seed=args.seed)
        init_path = os.path.join(out, f"{name}_init.npz")
        np.savez(init_path, **{k: np.asarray(v) for k, v in params.items()})
        entry["init"] = os.path.basename(init_path)

        for b in TRAIN_BATCHES[name]:
            fn = f"{name}_train_b{b}.hlo.txt"
            sha = write(os.path.join(out, fn), to_hlo_text(lower_train(name, b)))
            entry["train"][str(b)] = {"file": fn, "sha256_16": sha}
            print(f"  lowered {fn} ({sha})")

        fn = f"{name}_eval_b{EVAL_BATCH}.hlo.txt"
        sha = write(os.path.join(out, fn), to_hlo_text(lower_eval(name, EVAL_BATCH)))
        entry["eval"][str(EVAL_BATCH)] = {"file": fn, "sha256_16": sha}
        print(f"  lowered {fn} ({sha})")

        if not args.skip_golden:
            gb = min(TRAIN_BATCHES[name])
            g = golden_vectors(name, gb, params)
            gpath = os.path.join(out, f"{name}_golden.npz")
            np.savez(gpath, **g)
            entry["golden"] = {"file": os.path.basename(gpath),
                               "batch": gb, "lr": 0.01}
            print(f"  golden  {os.path.basename(gpath)} "
                  f"(loss={float(g['loss']):.6f})")

        manifest["models"][name] = entry

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(out, 'manifest.json')}")


if __name__ == "__main__":
    main()
