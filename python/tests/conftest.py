"""Make the `compile` package importable regardless of invocation cwd
(`pytest python/tests/` from the repo root, or `pytest tests/` from
python/)."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
