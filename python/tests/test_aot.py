"""AOT pipeline gate: manifest contract, HLO text sanity, npz round-trips."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "artifacts"))
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))

needs_artifacts = pytest.mark.skipif(
    not HAVE_ARTIFACTS, reason="run `make artifacts` first")


def test_to_hlo_text_produces_parseable_module():
    lowered = aot.lower_train("mlp", 16)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # flat positional signature: 4 param leaves + x + y + lr
    assert _entry_param_count(text) == 7


def _entry_param_count(text):
    """Number of parameters of the ENTRY computation."""
    entry = text[text.index("ENTRY "):]
    seen = set()
    for line in entry.splitlines():
        if "= parameter(" in line.replace(" ", "= parameter(") or "parameter(" in line:
            if "parameter(" in line and "=" in line:
                n = line.split("parameter(")[1].split(")")[0]
                seen.add(n)
    return len(seen)


def test_lower_eval_signature():
    text = aot.to_hlo_text(aot.lower_eval("mlp", aot.EVAL_BATCH))
    assert text.startswith("HloModule")
    assert _entry_param_count(text) == 6  # 4 leaves + x + y


@needs_artifacts
def test_manifest_contract():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["format"] == "hlo-text"
    for name, batches in aot.TRAIN_BATCHES.items():
        entry = man["models"][name]
        assert [p["name"] for p in entry["params"]] == M.param_order(name)
        assert entry["param_count"] == M.param_count(name)
        assert entry["update_bytes"] == 4 * M.param_count(name)
        for b in batches:
            f_ = entry["train"][str(b)]["file"]
            assert os.path.exists(os.path.join(ART, f_)), f_
        for b, info in entry["eval"].items():
            assert os.path.exists(os.path.join(ART, info["file"]))
        assert os.path.exists(os.path.join(ART, entry["init"]))
        assert os.path.exists(os.path.join(ART, entry["golden"]["file"]))


@needs_artifacts
@pytest.mark.parametrize("name", list(aot.TRAIN_BATCHES))
def test_init_npz_matches_specs(name):
    data = np.load(os.path.join(ART, f"{name}_init.npz"))
    for leaf, shape in M.param_specs(name):
        assert data[leaf].shape == tuple(shape)
        assert data[leaf].dtype == np.float32


@needs_artifacts
def test_golden_reproducible():
    """Golden vectors must be exactly reproducible from seeds."""
    params = M.init_params("mlp", seed=0)
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    gb = man["models"]["mlp"]["golden"]["batch"]
    g = aot.golden_vectors("mlp", gb, params)
    stored = np.load(os.path.join(ART, "mlp_golden.npz"))
    np.testing.assert_array_equal(g["x"], stored["x"])
    np.testing.assert_allclose(g["loss"], stored["loss"], rtol=1e-6)
    np.testing.assert_allclose(g["new_fc1_w"], stored["new_fc1_w"],
                               rtol=1e-6, atol=1e-7)


@needs_artifacts
def test_hlo_files_start_with_module_header():
    for fn in os.listdir(ART):
        if fn.endswith(".hlo.txt"):
            with open(os.path.join(ART, fn)) as f:
                head = f.read(16)
            assert head.startswith("HloModule"), fn
