"""L1 gate: Pallas conv-as-nine-GEMMs vs lax conv oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, ref

FAST = settings(max_examples=10, deadline=None)


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@FAST
@given(
    n=st.integers(1, 3),
    h=st.integers(3, 14),
    w=st.integers(3, 14),
    c=st.integers(1, 4),
    k=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_lax(n, h, w, c, k, seed):
    x = _rand((n, h, w, c), seed)
    wt = _rand((3, 3, c, k), seed + 1)
    got = conv.conv3x3_same(x, wt)
    want = ref.conv3x3_same(x, wt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 3, 3, 1, 1), (2, 28, 28, 1, 8),
                                   (1, 8, 8, 3, 16)])
def test_conv_known_shapes(shape):
    n, h, w, c, k = shape
    x = _rand((n, h, w, c), 0)
    wt = _rand((3, 3, c, k), 1)
    np.testing.assert_allclose(
        np.asarray(conv.conv3x3_same(x, wt)),
        np.asarray(ref.conv3x3_same(x, wt)),
        rtol=1e-4, atol=1e-4)


def test_conv_identity_kernel():
    # delta kernel at center ⇒ identity
    x = _rand((1, 6, 6, 2), 3)
    wt = np.zeros((3, 3, 2, 2), np.float32)
    wt[1, 1, 0, 0] = 1.0
    wt[1, 1, 1, 1] = 1.0
    out = conv.conv3x3_same(x, jnp.asarray(wt))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-5,
                               atol=1e-5)


def test_conv_grads_match_lax_grads():
    x = _rand((2, 10, 10, 3), 5)
    wt = _rand((3, 3, 3, 4), 6)

    def lk(x, w):
        return jnp.sum(conv.conv3x3_same(x, w) ** 2)

    def lr(x, w):
        return jnp.sum(ref.conv3x3_same(x, w) ** 2)

    gk = jax.grad(lk, argnums=(0, 1))(x, wt)
    gr = jax.grad(lr, argnums=(0, 1))(x, wt)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_shift_roundtrip():
    x = _rand((1, 5, 5, 1), 7)
    for ky in range(3):
        for kx in range(3):
            s = conv._shifted(x, ky, kx)
            u = conv._unshifted(s, ky, kx)
            # unshift(shift(x)) equals x on the interior that survived
            interior = np.asarray(u)[0, 1:-1, 1:-1, 0]
            expect = np.asarray(x)[0, 1:-1, 1:-1, 0]
            if ky == 1 and kx == 1:
                np.testing.assert_allclose(np.asarray(u), np.asarray(x))
            else:
                assert interior.shape == expect.shape


def test_conv_rejects_non_3x3():
    x = _rand((1, 5, 5, 2), 0)
    w5 = _rand((5, 5, 2, 2), 1)
    with pytest.raises(AssertionError):
        conv.conv3x3_same(x, w5)
