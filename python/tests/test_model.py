"""L2 gate: model shapes, loss semantics, gradient correctness, trainability."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M


@pytest.mark.parametrize("name", list(M.MODELS))
def test_param_specs_order_and_count(name):
    specs = M.param_specs(name)
    order = M.param_order(name)
    assert order == [n for n, _ in specs]
    assert len(set(order)) == len(order)
    count = sum(int(np.prod(s)) for _, s in specs)
    assert count == M.param_count(name)
    assert count > 0


@pytest.mark.parametrize("name", list(M.MODELS))
def test_init_params_match_specs(name):
    params = M.init_params(name, seed=0)
    for leaf, shape in M.param_specs(name):
        assert params[leaf].shape == shape
        assert params[leaf].dtype == jnp.float32
    # biases start at zero, weights don't
    assert float(jnp.abs(params[M.param_order(name)[1]]).sum()) == 0.0
    assert float(jnp.abs(params[M.param_order(name)[0]]).sum()) > 0.0


def test_init_params_deterministic():
    a = M.init_params("mlp", seed=3)
    b = M.init_params("mlp", seed=3)
    c = M.init_params("mlp", seed=4)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert any(
        not np.array_equal(np.asarray(a[k]), np.asarray(c[k])) for k in a)


@pytest.mark.parametrize("name,batch", [("mlp", 4), ("mnist_cnn", 2),
                                        ("cifar_cnn", 2)])
def test_forward_shapes(name, batch):
    params = M.init_params(name)
    x, _ = M.example_batch(name, batch)
    logits = M.forward(name, params, x)
    assert logits.shape == (batch, M.MODELS[name]["classes"])
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_uniform_logits_is_log_classes():
    # Zeroed params ⇒ logits 0 ⇒ loss = log(10)
    params = {k: jnp.zeros_like(v) for k, v in M.init_params("mlp").items()}
    x, y = M.example_batch("mlp", 8)
    loss = M.loss_fn("mlp", params, x, y)
    np.testing.assert_allclose(float(loss), np.log(10.0), rtol=1e-5)


def test_grad_matches_numeric_mlp():
    params = M.init_params("mlp", seed=1)
    x, y = M.example_batch("mlp", 4)
    g = jax.grad(lambda p: M.loss_fn("mlp", p, x, y))(params)
    # central differences on a few coordinates of each leaf
    eps = 1e-3
    rng = np.random.default_rng(0)
    for leaf in ["fc1_w", "fc2_b"]:
        arr = np.asarray(params[leaf])
        flat_idx = rng.choice(arr.size, size=3, replace=False)
        for fi in flat_idx:
            idx = np.unravel_index(fi, arr.shape)
            pp = {k: np.asarray(v).copy() for k, v in params.items()}
            pp[leaf][idx] += eps
            lp = float(M.loss_fn("mlp", {k: jnp.asarray(v) for k, v in pp.items()}, x, y))
            pp[leaf][idx] -= 2 * eps
            lm = float(M.loss_fn("mlp", {k: jnp.asarray(v) for k, v in pp.items()}, x, y))
            num = (lp - lm) / (2 * eps)
            ana = float(np.asarray(g[leaf])[idx])
            assert abs(num - ana) < 5e-3, (leaf, idx, num, ana)


@pytest.mark.parametrize("name,batch", [("mlp", 16), ("mnist_cnn", 8)])
def test_train_step_decreases_loss_on_fixed_batch(name, batch):
    order = M.param_order(name)
    params = M.init_params(name, seed=0)
    x, y = M.example_batch(name, batch)
    step = jax.jit(M.train_step(name))
    leaves = [params[k] for k in order]
    first = None
    for _ in range(8):
        out = step(*leaves, x, y, jnp.float32(0.05))
        leaves, loss = list(out[:-1]), float(out[-1])
        if first is None:
            first = loss
    assert loss < first, f"loss did not decrease: {first} -> {loss}"


def test_train_step_signature_roundtrip():
    """Output leaf order must equal input leaf order (manifest contract)."""
    order = M.param_order("mlp")
    params = M.init_params("mlp", seed=0)
    x, y = M.example_batch("mlp", 16)
    out = jax.jit(M.train_step("mlp"))(
        *[params[k] for k in order], x, y, jnp.float32(0.0))
    # lr=0 ⇒ new leaves identical to inputs, in the same order
    for k, new in zip(order, out[:-1]):
        np.testing.assert_array_equal(np.asarray(new), np.asarray(params[k]))


def test_eval_step_counts():
    order = M.param_order("mlp")
    params = M.init_params("mlp", seed=0)
    x, y = M.example_batch("mlp", 32)
    loss_sum, correct = jax.jit(M.eval_step("mlp"))(
        *[params[k] for k in order], x, y)
    assert 0.0 <= float(correct) <= 32.0
    assert float(loss_sum) > 0.0
    # cross-check vs loss_fn (mean * batch)
    mean_loss = M.loss_fn("mlp", params, x, y)
    np.testing.assert_allclose(float(loss_sum), float(mean_loss) * 32,
                               rtol=1e-4)


def test_example_batch_deterministic_and_bounded():
    x1, y1 = M.example_batch("mnist_cnn", 4, seed=0)
    x2, y2 = M.example_batch("mnist_cnn", 4, seed=0)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert float(x1.min()) >= 0.0 and float(x1.max()) <= 1.0
    assert int(y1.min()) >= 0 and int(y1.max()) < 10
