"""L1 gate: Pallas kernels vs pure-jnp oracles (hypothesis shape sweeps)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_linear as fl
from compile.kernels import ref, sgd

# interpret-mode Pallas is slow; keep case counts tight but the shape space
# broad (primes, 1-sized dims, > tile sizes).
FAST = settings(max_examples=25, deadline=None)


def _rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


def _tol(k):
    # k-blocked accumulation reassociates; tolerance scales with sqrt(k).
    return dict(rtol=5e-4, atol=5e-4 * np.sqrt(k))


# --------------------------------------------------------------------- linear

@FAST
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 300),
    n=st.integers(1, 160),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_matches_ref(m, k, n, act, seed):
    x = _rand((m, k), seed)
    w = _rand((k, n), seed + 1)
    b = _rand((n,), seed + 2)
    got = fl.linear(x, w, b, act)
    want = ref.linear(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(k))


@FAST
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 256),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand((m, k), seed)
    w = _rand((k, n), seed + 1)
    got = fl.matmul(x, w)
    want = ref.matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(k))


@pytest.mark.parametrize("mkn", [(1, 1, 1), (128, 128, 128), (32, 784, 128),
                                 (64, 2048, 128), (17, 131, 13)])
@pytest.mark.parametrize("act", ["none", "relu"])
def test_linear_known_shapes(mkn, act):
    m, k, n = mkn
    x, w, b = _rand((m, k), 3), _rand((k, n), 4), _rand((n,), 5)
    got = fl.linear(x, w, b, act)
    want = ref.linear(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(k))


def test_linear_bf16_inputs_accumulate_f32():
    x = _rand((16, 64), 0).astype(jnp.bfloat16)
    w = _rand((64, 32), 1).astype(jnp.bfloat16)
    b = _rand((32,), 2)
    got = fl.linear(x, w, b, "none")
    want = ref.linear(x, w, b, "none")
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_linear_rejects_bad_activation():
    x, w, b = _rand((4, 4), 0), _rand((4, 4), 1), _rand((4,), 2)
    with pytest.raises(ValueError):
        fl.linear(x, w, b, "gelu")


def test_linear_grad_matches_ref_grad():
    x, w, b = _rand((32, 112), 0), _rand((112, 48), 1), _rand((48,), 2)

    def lk(x, w, b):
        return jnp.sum(fl.linear_vjp(x, w, b, "relu") ** 2)

    def lr(x, w, b):
        return jnp.sum(ref.linear(x, w, b, "relu") ** 2)

    gk = jax.grad(lk, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(lr, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-3, atol=5e-3)


def test_tile_helper_divides():
    for dim in [1, 2, 7, 128, 784, 2048, 999]:
        for pref in [1, 32, 128, 4096]:
            t = fl._largest_divisor_tile(dim, pref)
            assert 1 <= t <= min(dim, pref)
            assert dim % t == 0


def test_vmem_budget_under_16mib():
    # The tiling the artifacts actually use must fit VMEM with headroom.
    for (m, n, k) in [(64, 128, 2048), (256, 128, 784), (128, 10, 128)]:
        assert fl.vmem_bytes(m, n, k) < 4 * 1024 * 1024  # 4 MiB << 16 MiB


# ------------------------------------------------------------------------ sgd

@FAST
@given(
    n=st.integers(1, 70000),
    lr=st.floats(1e-4, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_flat_matches_ref(n, lr, seed):
    w = _rand((n,), seed)
    g = _rand((n,), seed + 1)
    got = sgd.sgd_update(w, g, lr)
    want = ref.sgd_update(w, g, lr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [(3, 3, 1, 8), (784, 128), (10,), (1,),
                                   (2, 2, 2, 2)])
def test_sgd_shapes(shape):
    w, g = _rand(shape, 0), _rand(shape, 1)
    got = sgd.sgd_update(w, g, 0.01)
    want = ref.sgd_update(w, g, 0.01)
    assert got.shape == w.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_sgd_tree():
    params = {"a": _rand((8, 8), 0), "b": _rand((8,), 1)}
    grads = {"a": _rand((8, 8), 2), "b": _rand((8,), 3)}
    new = sgd.sgd_update_tree(params, grads, 0.5)
    for k in params:
        np.testing.assert_allclose(
            np.asarray(new[k]),
            np.asarray(ref.sgd_update(params[k], grads[k], 0.5)),
            rtol=1e-6, atol=1e-6)


def test_sgd_zero_lr_is_identity():
    w, g = _rand((100,), 0), _rand((100,), 1)
    np.testing.assert_array_equal(np.asarray(sgd.sgd_update(w, g, 0.0)),
                                  np.asarray(w))


def test_sgd_shape_mismatch_raises():
    with pytest.raises(AssertionError):
        sgd.sgd_update(_rand((4,), 0), _rand((5,), 1), 0.1)
