#!/usr/bin/env python3
"""Warn-only perf-trajectory diff for the BENCH_*.json reports.

Compares a fresh bench run (``Suite::to_json`` output, uploaded by CI as
the BENCH_hotpath artifact) against the committed baseline and prints
GitHub workflow annotations for per-benchmark mean-time regressions
beyond a threshold. It never fails the build (always exits 0): the CI
smoke lane runs tiny iteration counts (``DEFL_BENCH_FAST=1``) on shared
runners, so this is a visibility tool, not a gate — the point is that
every PR shows its perf trajectory next to its diff.

Refresh the baseline by copying a trusted run's ``BENCH_hotpath.json``
artifact over the committed file at the repo root.

Usage: bench_diff.py BASELINE FRESH [--warn-pct 25]
"""

import argparse
import json
import sys


def load_results(path):
    with open(path) as f:
        report = json.load(f)
    out = {}
    for r in report.get("results", []):
        mean = r.get("mean_s")
        if isinstance(mean, (int, float)) and mean > 0:
            out[r["name"]] = mean
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--warn-pct", type=float, default=25.0)
    args = ap.parse_args()

    try:
        base = load_results(args.baseline)
    except (OSError, ValueError) as e:
        print(f"bench_diff: unusable baseline {args.baseline!r} ({e}) — recording only")
        base = {}
    try:
        fresh = load_results(args.fresh)
    except (OSError, ValueError) as e:
        print(f"::warning::bench_diff: unusable fresh report {args.fresh!r} ({e})")
        return 0

    if not base:
        print(f"bench_diff: baseline empty — no comparison; {len(fresh)} fresh benchmarks:")
        for name, mean in sorted(fresh.items()):
            print(f"  {name}: mean {mean:.3e}s")
        print("bench_diff: commit a trusted BENCH_hotpath.json to start the trajectory")
        return 0

    regressions = 0
    for name, mean in sorted(fresh.items()):
        if name not in base:
            print(f"  NEW  {name}: mean {mean:.3e}s (no baseline)")
            continue
        pct = (mean / base[name] - 1.0) * 100.0
        marker = " "
        if pct > args.warn_pct:
            regressions += 1
            marker = "!"
            print(
                f"::warning::perf regression: {name} mean {mean:.3e}s vs "
                f"baseline {base[name]:.3e}s (+{pct:.1f}% > {args.warn_pct:.0f}%)"
            )
        print(f"  {marker}    {name}: {pct:+.1f}% vs baseline")
    for name in sorted(set(base) - set(fresh)):
        print(f"::warning::benchmark disappeared from the suite: {name}")

    print(
        f"bench_diff: {len(fresh)} benchmarks, {regressions} regression(s) "
        f"beyond {args.warn_pct:.0f}% (warn-only)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
