#!/usr/bin/env python3
"""Perf-trajectory diff for the BENCH_*.json reports.

Compares a fresh bench run (``Suite::to_json`` output, uploaded by CI as
the BENCH_hotpath artifact) against the committed baseline and prints
GitHub workflow annotations for per-benchmark mean-time regressions
beyond a threshold. By default it never fails the build on a
*comparison*: the CI smoke lane runs tiny iteration counts
(``DEFL_BENCH_FAST=1``) on shared runners, so the comparison is a
visibility tool — the point is that every PR shows its perf trajectory
next to its diff.

``--fail-pct`` arms the gate for the benchmark families named by
``--fail-families`` (comma-separated name prefixes): once a non-empty
baseline exists, an enforced-family regression beyond ``--fail-pct`` or
an enforced-family benchmark missing from the fresh report prints a
``::error::`` annotation and exits 1. Families outside the list stay
warn-only at ``--warn-pct``, and with no baseline the gate cannot fire
(the NO BASELINE state below is unchanged), so committing the first
trusted baseline is what arms enforcement.

Exit codes: 0 means a comparison happened (or there was nothing to
measure); ``EXIT_NO_BASELINE`` (3) means the fresh report was fine but
the baseline was missing/empty, so *no comparison happened at all* — a
distinct code so CI can record the state honestly instead of a green
check pretending a diff ran. The NO BASELINE path prints a banner and
the fresh numbers once.

Other degenerate inputs degrade to single informational lines, never to
a warning wall: an empty fresh report means "nothing measured" (no
per-benchmark "disappeared" annotations).

Refresh the baseline by copying a trusted run's ``BENCH_hotpath.json``
artifact over the committed file at the repo root, or run with
``--promote``: in the NO BASELINE state it copies the fresh report over
the baseline path and exits 0, so the *next* run diffs for real. When a
baseline already exists ``--promote`` changes nothing — committed
baselines stay authoritative; overwrite them deliberately.

Usage: bench_diff.py BASELINE FRESH [--warn-pct 25] [--promote]
                     [--fail-pct 25 --fail-families codec_fold_,fedavg_stream_]
       bench_diff.py --self-test
"""

import argparse
import json
import sys

# The fresh report measured fine but there was no baseline to diff
# against — no comparison happened. Distinct from 0 so CI can tell
# "trajectory recorded" apart from "trajectory not started yet".
EXIT_NO_BASELINE = 3


def load_results(path):
    """{name: mean_s} from a Suite::to_json report.

    Tolerant by design: a missing file raises (the caller decides how
    loud to be), but a report whose ``results`` is absent, null, not a
    list, or populated with malformed entries yields whatever valid
    entries remain — an empty dict at worst, never an exception.
    """
    with open(path) as f:
        report = json.load(f)
    out = {}
    results = report.get("results") if isinstance(report, dict) else None
    if not isinstance(results, list):
        return out
    for r in results:
        if not isinstance(r, dict):
            continue
        name = r.get("name")
        mean = r.get("mean_s")
        if isinstance(name, str) and isinstance(mean, (int, float)) and mean > 0:
            out[name] = mean
    return out


def compare(base, fresh, warn_pct):
    """Diff two {name: mean_s} maps into (lines, warnings).

    ``lines`` are plain report lines; ``warnings`` are GitHub
    ``::warning::`` annotation bodies (regressions + disappearances).
    Pure function — the self-test runs on it directly.
    """
    lines, warnings = [], []
    if not fresh:
        lines.append("bench_diff: fresh report has no benchmarks — nothing to compare")
        return lines, warnings
    if not base:
        lines.append(
            f"bench_diff: NO BASELINE — no comparison ran; {len(fresh)} fresh benchmarks:"
        )
        for name, mean in sorted(fresh.items()):
            lines.append(f"  {name}: mean {mean:.3e}s")
        lines.append(
            "bench_diff: commit a trusted BENCH_hotpath.json to start the trajectory"
            f" (exit {EXIT_NO_BASELINE})"
        )
        return lines, warnings

    for name, mean in sorted(fresh.items()):
        if name not in base:
            lines.append(f"  NEW  {name}: mean {mean:.3e}s (no baseline)")
            continue
        pct = (mean / base[name] - 1.0) * 100.0
        marker = " "
        if pct > warn_pct:
            marker = "!"
            warnings.append(
                f"perf regression: {name} mean {mean:.3e}s vs "
                f"baseline {base[name]:.3e}s (+{pct:.1f}% > {warn_pct:.0f}%)"
            )
        lines.append(f"  {marker}    {name}: {pct:+.1f}% vs baseline")
    for name in sorted(set(base) - set(fresh)):
        warnings.append(f"benchmark disappeared from the suite: {name}")
    n_reg = sum(1 for w in warnings if w.startswith("perf regression"))
    lines.append(
        f"bench_diff: {len(fresh)} benchmarks, {n_reg} regression(s) "
        f"beyond {warn_pct:.0f}% (warn-only)"
    )
    return lines, warnings


def enforce(base, fresh, fail_pct, families):
    """Failing-gate errors: ``::error::`` bodies, empty when the gate holds.

    Only benchmarks whose name starts with one of ``families`` are
    enforced, and only against a non-empty baseline — the NO BASELINE
    state stays warn-free so the gate self-arms when the first trusted
    baseline is committed. Two ways to trip it: an enforced benchmark
    regressing beyond ``fail_pct``, and an enforced baseline benchmark
    missing from the fresh report (a gate you can delete is no gate).
    Pure function — the self-test runs on it directly.
    """
    errors = []
    if fail_pct is None or not families or not base or not fresh:
        return errors
    def enforced(name):
        return any(name.startswith(f) for f in families)

    for name, mean in sorted(fresh.items()):
        if name not in base or not enforced(name):
            continue
        pct = (mean / base[name] - 1.0) * 100.0
        if pct > fail_pct:
            errors.append(
                f"perf gate: {name} mean {mean:.3e}s vs baseline "
                f"{base[name]:.3e}s (+{pct:.1f}% > {fail_pct:.0f}%)"
            )
    for name in sorted(set(base) - set(fresh)):
        if enforced(name):
            errors.append(f"perf gate: enforced benchmark missing from fresh report: {name}")
    return errors


def exit_code(base, fresh):
    """0 when a comparison ran (or nothing was measured), else NO BASELINE.

    Pure companion to ``compare`` — the self-test pins the exit contract
    without shelling out.
    """
    return EXIT_NO_BASELINE if fresh and not base else 0


def should_promote(base, fresh, promote):
    """Whether --promote fires: only in the NO BASELINE state, and only
    when the fresh report actually measured something worth seeding."""
    return bool(promote and fresh and not base)


def self_test():
    """Pytest-free smoke of the load/compare pipeline (CI lint job)."""
    import os
    import tempfile

    def write(doc):
        fd, path = tempfile.mkstemp(suffix=".json")
        with os.fdopen(fd, "w") as f:
            f.write(doc if isinstance(doc, str) else json.dumps(doc))
        return path

    paths = []
    try:
        # -- load_results tolerance -----------------------------------
        ok = write({"results": [{"name": "a", "mean_s": 1.0}, {"name": "z"}]})
        paths.append(ok)
        assert load_results(ok) == {"a": 1.0}, "valid entries survive, malformed skipped"
        for doc in ({}, {"results": None}, {"results": "oops"}, {"results": []}, [1, 2]):
            p = write(doc)
            paths.append(p)
            assert load_results(p) == {}, f"degenerate results must load empty: {doc!r}"
        bad = write("{not json")
        paths.append(bad)
        try:
            load_results(bad)
            raise AssertionError("malformed JSON must raise for the caller to report")
        except ValueError:
            pass

        # -- compare: degenerate shapes are single lines, not walls ----
        lines, warns = compare({"a": 1.0, "b": 2.0}, {}, 25.0)
        assert warns == [], "empty fresh report must not spray 'disappeared' warnings"
        assert len(lines) == 1 and "nothing to compare" in lines[0]
        lines, warns = compare({}, {"a": 1.0}, 25.0)
        assert warns == [], "empty baseline is informational"
        assert lines[0].startswith("bench_diff: NO BASELINE"), "banner leads the report"
        assert any("a: mean" in ln for ln in lines), "fresh numbers still listed once"
        # the no-baseline state gets its own exit code, distinct from both
        # success (0) and argparse/IO failure, so CI can record it honestly
        assert EXIT_NO_BASELINE not in (0, 1, 2)
        assert exit_code({}, {"a": 1.0}) == EXIT_NO_BASELINE
        assert exit_code({"a": 1.0}, {"a": 1.0}) == 0, "a real comparison exits 0"
        assert exit_code({}, {}) == 0, "nothing measured is not the no-baseline state"

        # -- --promote fires only in the NO BASELINE state -------------
        assert should_promote({}, {"a": 1.0}, True), "no baseline + fresh → promote"
        assert not should_promote({"a": 1.0}, {"a": 2.0}, True), "baseline is authoritative"
        assert not should_promote({}, {}, True), "nothing measured seeds nothing"
        assert not should_promote({}, {"a": 1.0}, False), "promotion is opt-in"

        # -- compare: the actual diff ---------------------------------
        base = {"a": 1.0, "b": 1.0, "gone": 1.0}
        fresh = {"a": 2.0, "b": 1.05, "new": 3.0}
        lines, warns = compare(base, fresh, 25.0)
        assert any(w.startswith("perf regression: a ") for w in warns), "a regressed 100%"
        assert not any("regression: b" in w for w in warns), "b is within threshold"
        assert any("disappeared" in w and "gone" in w for w in warns)
        assert any("NEW" in ln and "new" in ln for ln in lines)
        # improvements never warn
        _, warns = compare({"a": 2.0}, {"a": 1.0}, 25.0)
        assert warns == []

        # -- enforce: the promoted-baseline gate ----------------------
        fams = ["codec_fold_", "fedavg_stream_"]
        base = {"codec_fold_q8": 1.0, "fedavg_stream_100": 1.0, "setup_misc": 1.0}
        # regression over a non-empty baseline in an enforced family fails
        errs = enforce(base, {**base, "codec_fold_q8": 2.0}, 25.0, fams)
        assert len(errs) == 1 and "codec_fold_q8" in errs[0], "enforced regression trips"
        # an improvement (or within-threshold noise) passes
        assert enforce(base, {**base, "codec_fold_q8": 0.5}, 25.0, fams) == []
        assert enforce(base, {**base, "fedavg_stream_100": 1.2}, 25.0, fams) == []
        # a missing enforced bench name fails — deleting the bench is not a fix
        errs = enforce(base, {"codec_fold_q8": 1.0, "setup_misc": 1.0}, 25.0, fams)
        assert len(errs) == 1 and "missing" in errs[0] and "fedavg_stream_100" in errs[0]
        # non-enforced families stay warn-only however badly they regress
        assert enforce(base, {**base, "setup_misc": 9.0}, 25.0, fams) == []
        assert enforce(base, dict(base), 25.0, fams) == [], "clean run passes"
        # the gate cannot fire before a baseline exists (self-arming) or
        # when enforcement was never requested
        assert enforce({}, {"codec_fold_q8": 9.0}, 25.0, fams) == []
        assert enforce(base, {**base, "codec_fold_q8": 9.0}, None, fams) == []
        assert enforce(base, {**base, "codec_fold_q8": 9.0}, 25.0, []) == []
    finally:
        for p in paths:
            os.unlink(p)
    print("bench_diff: self-test OK")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", nargs="?")
    ap.add_argument("fresh", nargs="?")
    ap.add_argument("--warn-pct", type=float, default=25.0)
    ap.add_argument(
        "--promote",
        action="store_true",
        help="seed BASELINE from FRESH when no baseline exists (exit 0 instead of 3)",
    )
    ap.add_argument(
        "--fail-pct",
        type=float,
        default=None,
        help="fail (exit 1) on enforced-family regressions beyond this percentage",
    )
    ap.add_argument(
        "--fail-families",
        default="",
        help="comma-separated benchmark-name prefixes the --fail-pct gate enforces",
    )
    ap.add_argument(
        "--self-test", action="store_true", help="run the built-in assertions and exit"
    )
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if not args.baseline or not args.fresh:
        ap.error("BASELINE and FRESH are required unless --self-test")

    try:
        base = load_results(args.baseline)
    except (OSError, ValueError) as e:
        print(f"bench_diff: unusable baseline {args.baseline!r} ({e}) — recording only")
        base = {}
    try:
        fresh = load_results(args.fresh)
    except (OSError, ValueError) as e:
        print(f"::warning::bench_diff: unusable fresh report {args.fresh!r} ({e})")
        return 0

    lines, warnings = compare(base, fresh, args.warn_pct)
    families = [f for f in args.fail_families.split(",") if f]
    errors = enforce(base, fresh, args.fail_pct, families)
    for w in warnings:
        print(f"::warning::{w}")
    for e in errors:
        print(f"::error::{e}")
    for ln in lines:
        print(ln)
    if errors:
        print(f"bench_diff: perf gate FAILED — {len(errors)} enforced violation(s)")
        return 1
    if should_promote(base, fresh, args.promote):
        import shutil

        shutil.copyfile(args.fresh, args.baseline)
        print(
            f"bench_diff: promoted {args.fresh} -> {args.baseline} "
            f"({len(fresh)} benchmarks seed the trajectory; commit it to keep it)"
        )
        return 0
    return exit_code(base, fresh)


if __name__ == "__main__":
    sys.exit(main())
