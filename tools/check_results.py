#!/usr/bin/env python3
"""Strict schema gate for every harness output (DESIGN.md §12).

Every document the trial runner or a figure formatter writes — per-trial
``result.json`` files, per-spec aggregates, figure documents — must
carry a numeric ``schema_version`` equal to the supported version and a
non-empty string ``spec`` naming the experiment spec that produced it.
Unversioned or mis-attributed files are rejected loudly: downstream
plotting must never guess at a file's shape, and a result that can't
say which spec produced it is not reproducible. Mirrors
``defl::harness::validate_result_doc``.

Beyond the version/provenance gate, the checker knows the three document
shapes and applies the matching structural checks:

* trial documents (``outcome`` present): outcome must be ``success`` or
  ``error``, ``objective`` must be ``{name, value}``, ``metrics`` a dict;
* aggregates (``variants`` present): every variant entry needs ``n``,
  ``failed`` and an ``objective`` with ``mean``/``ci95``;
* figure documents (``figure`` present): ``provenance`` must name the
  spec and seed plan.

Exit codes: 0 all files pass; 1 any file fails (each failure printed as
a GitHub ``::error::`` annotation); 2 usage errors.

Usage: check_results.py FILE_OR_DIR [FILE_OR_DIR ...]
       check_results.py --self-test
"""

import argparse
import json
import os
import sys

SCHEMA_VERSION = 1


def fail(path, msg):
    return f"{path}: {msg}"


def check_common(path, doc):
    """The gate itself: version + provenance, on every document."""
    errors = []
    if not isinstance(doc, dict):
        return [fail(path, "result document must be a JSON object")]
    version = doc.get("schema_version")
    if not isinstance(version, (int, float)) or isinstance(version, bool):
        errors.append(fail(path, "missing or non-numeric schema_version"))
    elif version != SCHEMA_VERSION:
        errors.append(
            fail(path, f"schema_version {version} != supported {SCHEMA_VERSION}")
        )
    spec = doc.get("spec")
    if not isinstance(spec, str) or not spec:
        errors.append(fail(path, "missing or empty `spec` provenance"))
    return errors


def check_trial(path, doc):
    errors = []
    if doc.get("outcome") not in ("success", "error"):
        errors.append(fail(path, f"outcome must be success|error, got {doc.get('outcome')!r}"))
    objective = doc.get("objective")
    if not isinstance(objective, dict) or "name" not in objective or "value" not in objective:
        errors.append(fail(path, "objective must be an object with name and value"))
    if not isinstance(doc.get("metrics"), dict):
        errors.append(fail(path, "metrics must be an object"))
    if doc.get("outcome") == "error" and not doc.get("error"):
        errors.append(fail(path, "error outcome without an error message"))
    return errors


def check_aggregate(path, doc):
    errors = []
    variants = doc.get("variants")
    if not isinstance(variants, list) or not variants:
        return [fail(path, "aggregate needs a non-empty `variants` array")]
    for i, v in enumerate(variants):
        where = f"variants[{i}]"
        if not isinstance(v, dict):
            errors.append(fail(path, f"{where} must be an object"))
            continue
        for key in ("variant", "n", "failed"):
            if key not in v:
                errors.append(fail(path, f"{where} missing {key!r}"))
        objective = v.get("objective")
        if not isinstance(objective, dict) or not {"mean", "ci95"} <= objective.keys():
            errors.append(fail(path, f"{where} objective needs mean and ci95"))
    return errors


def check_figure(path, doc):
    errors = []
    prov = doc.get("provenance")
    if not isinstance(prov, dict) or not prov.get("spec"):
        errors.append(fail(path, "figure document needs `provenance` naming its spec"))
    elif "base_seed" not in prov:
        errors.append(fail(path, "figure provenance missing base_seed"))
    if "attacks" in doc or doc.get("figure") == "ablation_attack":
        errors += check_attacks(path, doc)
    if "transport" in doc or doc.get("figure") == "ablation_transport":
        errors += check_transport(path, doc)
    return errors


AGGREGATORS = ("mean", "clip", "trimmed_mean", "median")


def check_attacks(path, doc):
    """The attack sweep's payload (DESIGN.md §13): every row names its
    aggregator, codec and attack fraction and carries the robustness
    counters; the headline paired delta must be present (null is allowed
    — it means the unprotected arm diverged past a finite loss)."""
    errors = []
    rows = doc.get("attacks")
    if not isinstance(rows, list) or not rows:
        return [fail(path, "attack figure needs a non-empty `attacks` array")]
    for i, r in enumerate(rows):
        where = f"attacks[{i}]"
        if not isinstance(r, dict):
            errors.append(fail(path, f"{where} must be an object"))
            continue
        for key in ("codec", "attack_fraction", "attacked_updates"):
            if key not in r:
                errors.append(fail(path, f"{where} missing {key!r}"))
        if r.get("aggregator") not in AGGREGATORS:
            errors.append(
                fail(path, f"{where} aggregator must be one of {AGGREGATORS}, "
                           f"got {r.get('aggregator')!r}")
            )
    if "attack_delta_pct" not in doc:
        errors.append(fail(path, "attack figure missing `attack_delta_pct`"))
    return errors


TRANSPORT_ROW_KEYS = (
    "engine",
    "codec",
    "chunk_loss_prob",
    "overall_time",
    "retransmits",
    "corrupt_detected",
    "gave_up",
    "backoff_s",
)

PLAN_KEYS = (
    "t_cm_base",
    "t_cm_true",
    "aware_overall_time",
    "blind_overall_time_under_truth",
    "margin_pct",
)


def check_transport(path, doc):
    """The transport sweep's payload (DESIGN.md §14): every grid row
    names its engine, codec and chunk-loss level and carries the ARQ
    counters; the loss-aware-pricing comparison must be present with
    both plans' predicted times under the true lossy link."""
    errors = []
    rows = doc.get("transport")
    if not isinstance(rows, list) or not rows:
        return [fail(path, "transport figure needs a non-empty `transport` array")]
    for i, r in enumerate(rows):
        where = f"transport[{i}]"
        if not isinstance(r, dict):
            errors.append(fail(path, f"{where} must be an object"))
            continue
        for key in TRANSPORT_ROW_KEYS:
            if key not in r:
                errors.append(fail(path, f"{where} missing {key!r}"))
    plan = doc.get("plan")
    if not isinstance(plan, dict):
        errors.append(fail(path, "transport figure needs a `plan` object"))
    else:
        for key in PLAN_KEYS:
            if key not in plan:
                errors.append(fail(path, f"plan missing {key!r}"))
    return errors


def check_doc(path, doc):
    """All errors for one parsed document (empty list = pass)."""
    errors = check_common(path, doc)
    if errors or not isinstance(doc, dict):
        return errors  # version gate failed; shape checks would be noise
    if "outcome" in doc:
        errors += check_trial(path, doc)
    elif "variants" in doc:
        errors += check_aggregate(path, doc)
    elif "figure" in doc:
        errors += check_figure(path, doc)
    return errors


def iter_files(targets):
    for target in targets:
        if os.path.isdir(target):
            for root, _dirs, files in sorted(os.walk(target)):
                for name in sorted(files):
                    if name.endswith(".json"):
                        yield os.path.join(root, name)
        else:
            yield target


def run(targets):
    n_checked, errors = 0, []
    for path in iter_files(targets):
        n_checked += 1
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            errors.append(fail(path, f"unreadable: {e}"))
            continue
        errors += check_doc(path, doc)
    return n_checked, errors


def self_test():
    """Pytest-free assertions over the pure checkers (CI lint job)."""
    ok_trial = {
        "schema_version": 1,
        "spec": "ci_matrix",
        "variant": "grid-sync",
        "seed": 42,
        "outcome": "success",
        "objective": {"name": "overall_time", "value": 12.5},
        "metrics": {"overall_time": 12.5},
    }
    assert check_doc("t", ok_trial) == []
    # the gate: unversioned and mis-versioned files are rejected
    assert check_doc("t", {"spec": "x", "outcome": "success"}), "unversioned must fail"
    assert check_doc("t", dict(ok_trial, schema_version="1")), "string version must fail"
    assert check_doc("t", dict(ok_trial, schema_version=2)), "future version must fail"
    assert check_doc("t", dict(ok_trial, schema_version=True)), "bool is not a version"
    assert check_doc("t", dict(ok_trial, spec="")), "empty spec provenance must fail"
    no_spec = dict(ok_trial)
    del no_spec["spec"]
    assert check_doc("t", no_spec), "missing spec provenance must fail"
    assert check_doc("t", [1, 2]), "non-object roots must fail"
    # trial shape
    assert check_doc("t", dict(ok_trial, outcome="flaky")), "unknown outcome must fail"
    assert check_doc("t", dict(ok_trial, objective={"name": "x"})), "objective.value"
    assert check_doc("t", dict(ok_trial, outcome="error")), "error without message"
    err_trial = dict(ok_trial, outcome="error", error="diverged")
    assert check_doc("t", err_trial) == [], "error trials with a message pass"
    # aggregate shape
    ok_agg = {
        "schema_version": 1,
        "spec": "ci_matrix",
        "variants": [
            {
                "variant": "grid-sync",
                "n": 6,
                "failed": 0,
                "objective": {"name": "overall_time", "mean": 1.0, "ci95": 0.1},
            }
        ],
    }
    assert check_doc("a", ok_agg) == []
    assert check_doc("a", dict(ok_agg, variants=[])), "empty variants must fail"
    bad_agg = dict(ok_agg, variants=[{"variant": "v", "n": 1}])
    assert check_doc("a", bad_agg), "variant without failed/objective must fail"
    # figure shape
    ok_fig = {
        "schema_version": 1,
        "spec": "fig2-mnist",
        "figure": "fig2_mnist",
        "provenance": {"spec": "fig2-mnist", "base_seed": 42},
        "series": [],
    }
    assert check_doc("f", ok_fig) == []
    assert check_doc("f", dict(ok_fig, provenance={})), "anonymous figure must fail"
    assert check_doc(
        "f", dict(ok_fig, provenance={"spec": "fig2-mnist"})
    ), "figure provenance without base_seed must fail"
    # attack-sweep shape (figure ablation_attack, or any doc carrying `attacks`)
    ok_row = {
        "aggregator": "median",
        "codec": "dense",
        "attack_fraction": 0.2,
        "attacked_updates": 12,
    }
    ok_attack = {
        "schema_version": 1,
        "spec": "ablation-attack",
        "figure": "ablation_attack",
        "provenance": {"spec": "ablation-attack", "base_seed": 42},
        "attacks": [ok_row],
        "attack_delta_pct": 152.3,
    }
    assert check_doc("k", ok_attack) == []
    assert check_doc("k", dict(ok_attack, attack_delta_pct=None)) == [], (
        "a null headline delta (diverged unprotected arm) passes"
    )
    assert check_doc("k", dict(ok_attack, attacks=[])), "empty attacks must fail"
    no_delta = dict(ok_attack)
    del no_delta["attack_delta_pct"]
    assert check_doc("k", no_delta), "missing attack_delta_pct must fail"
    bad_row = dict(ok_row, aggregator="krum")
    assert check_doc("k", dict(ok_attack, attacks=[bad_row])), "unknown aggregator must fail"
    thin_row = {"aggregator": "mean"}
    assert check_doc("k", dict(ok_attack, attacks=[thin_row])), "row missing keys must fail"
    # transport-sweep shape (figure ablation_transport, or any doc carrying `transport`)
    ok_tp_row = {
        "engine": "sync",
        "codec": "dense",
        "chunk_loss_prob": 0.1,
        "overall_time": 3.2,
        "retransmits": 41,
        "corrupt_detected": 1,
        "gave_up": 0,
        "backoff_s": 0.12,
    }
    ok_plan = {
        "t_cm_base": 0.042,
        "t_cm_true": 0.114,
        "aware_overall_time": 180.0,
        "blind_overall_time_under_truth": 186.0,
        "margin_pct": 3.2,
    }
    ok_tp = {
        "schema_version": 1,
        "spec": "ablation-transport",
        "figure": "ablation_transport",
        "provenance": {"spec": "ablation-transport", "base_seed": 42},
        "transport": [ok_tp_row],
        "plan": ok_plan,
        "plan_margin_pct": 3.2,
    }
    assert check_doc("p", ok_tp) == []
    assert check_doc("p", dict(ok_tp, transport=[])), "empty transport grid must fail"
    no_plan = dict(ok_tp)
    del no_plan["plan"]
    assert check_doc("p", no_plan), "missing plan comparison must fail"
    thin_plan = {"t_cm_base": 0.04}
    assert check_doc("p", dict(ok_tp, plan=thin_plan)), "plan missing keys must fail"
    thin_tp_row = {"engine": "sync"}
    assert check_doc("p", dict(ok_tp, transport=[thin_tp_row])), (
        "transport row missing ARQ counters must fail"
    )
    print("check_results: self-test OK")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("targets", nargs="*", help="result .json files or directories")
    ap.add_argument(
        "--self-test", action="store_true", help="run the built-in assertions and exit"
    )
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    if not args.targets:
        ap.error("give result files/directories to check, or --self-test")
    n_checked, errors = run(args.targets)
    for e in errors:
        print(f"::error::{e}")
    print(f"check_results: {n_checked} file(s), {len(errors)} error(s)")
    if n_checked == 0:
        print("::error::check_results: no .json files found to check")
        return 1
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
